"""All-reduce cost models (paper Table 2) and TPU interconnect models.

The paper models a single all-reduce of M bytes as

    T_ar(M) = a + b * M                                           (Eq. 10)

where ``a`` (startup / latency term) and ``b`` (per-byte term) derive from
the collective algorithm and the point-to-point link parameters:

    alpha : point-to-point latency (s)
    beta  : point-to-point transfer time per byte (s/B)
    gamma : reduction (summation) time per byte on one node (s/B)

Table 2 of the paper gives (a, b) for five classic algorithms.  We implement
all five, a least-squares fitter that recovers (a, b) from measured
(size, time) samples (paper Fig. 4), and a two-level hierarchical model for
TPU pods where the intra-pod ICI and the inter-pod DCN links have very
different (alpha, beta).

The key property exploited by MG-WFBP (paper Eq. 11) is super-additivity of
the startup term:

    T_ar(M1) + T_ar(M2) = 2a + b(M1+M2) > a + b(M1+M2) = T_ar(M1+M2)
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Mapping, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Hardware constants for the TPU v5e target (per the roofline brief).
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
HBM_BW = 819e9                # per chip, B/s
ICI_BW_PER_LINK = 50e9        # B/s per ICI link
ICI_ALPHA = 1e-6              # ~1 us per-hop startup on ICI
DCN_BW = 25e9                 # B/s effective per host across pods
DCN_ALPHA = 2.5e-4            # ~250 us startup for a cross-pod collective

# Paper-measured cluster constants (Fig. 4), used by the reproduction
# benchmarks.  (a in seconds, b in seconds/byte.)
PAPER_CLUSTERS = {
    # 8-node K80, 10GbE
    "cluster1_k80_10gbe": (9.72e-4, 1.97e-9),
    # 4-node V100, 10GbE
    "cluster2_v100_10gbe": (9.08e-4, 7.40e-10),
    # 4-node V100, 56Gb InfiniBand
    "cluster3_v100_ib": (2.36e-4, 4.06e-10),
}


@dataclasses.dataclass(frozen=True)
class AllReduceModel:
    """Linear all-reduce cost model ``T(M) = a + b * M`` (Eq. 10)."""

    a: float            # startup time, seconds
    b: float            # per-byte time, seconds/byte
    name: str = "linear"

    def __post_init__(self):
        if self.a < 0 or self.b < 0:
            raise ValueError(f"negative cost model parameters: a={self.a} b={self.b}")

    def time(self, nbytes: float) -> float:
        """Cost of all-reducing a message of ``nbytes`` bytes."""
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    def merge_gain(self, nbytes_1: float, nbytes_2: float) -> float:
        """Time saved by merging two messages into one (== a; Eq. 11/21)."""
        if nbytes_1 <= 0 or nbytes_2 <= 0:
            return 0.0
        return self.time(nbytes_1) + self.time(nbytes_2) - self.time(
            nbytes_1 + nbytes_2)

    def scaled(self, factor: float) -> "AllReduceModel":
        return AllReduceModel(self.a * factor, self.b * factor, self.name)


def blend(old: AllReduceModel, new: AllReduceModel,
          weight: float) -> AllReduceModel:
    """Damped model update: ``weight`` on the new estimate, rest on the old.

    The contention fixpoint (``planner.plan_contention_aware``) uses this to
    suppress plan/fit oscillation: a full-step update (weight=1) can flip
    between two plans whose observations each justify the other's model.
    """
    if not 0.0 <= weight <= 1.0:
        raise ValueError(f"blend weight must be in [0, 1], got {weight}")
    return AllReduceModel(old.a * (1 - weight) + new.a * weight,
                          old.b * (1 - weight) + new.b * weight,
                          new.name)


# ---------------------------------------------------------------------------
# Per-link path models.
#
# MG-WFBP only needs the communication model to be affine in the message
# size; it does NOT need the fabric to be one link.  A multi-phase
# collective (BlueConnect-style per-level stages: ICI reduce-scatter,
# DCN all-reduce on the shard, ICI all-gather) is a *sum* of per-link
# affine phases — still affine — so the planner stays exact while each
# link's (a_l, b_l) can be fit from that link's own telemetry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PathPhase:
    """One per-link leg of a collective's path.

    ``a``/``b`` are the phase's startup and per-byte cost *of the full
    message* — for a sharded leg (e.g. the cross-pod all-reduce on a
    1/intra_size shard) ``b`` already includes the shard dilution, so a
    phase's wall time for a message of M bytes is simply ``a + b*M``.
    ``shard_fraction`` records how many of M's bytes physically cross the
    link (per-link *byte* accounting: ``M * shard_fraction``), which is
    provenance the time model does not need but the telemetry
    conservation laws do.
    """

    link: str
    a: float                    # startup on this link, seconds
    b: float                    # seconds per byte of the FULL message
    shard_fraction: float = 1.0  # fraction of the message crossing the link

    def __post_init__(self):
        if self.a < 0 or self.b < 0:
            raise ValueError(f"negative phase cost: {self}")
        if not 0.0 < self.shard_fraction <= 1.0:
            raise ValueError(
                f"shard_fraction must be in (0, 1]: {self}")

    def time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    def link_bytes(self, nbytes: float) -> float:
        """Bytes this phase actually moves across its link."""
        return float(nbytes) * self.shard_fraction


@dataclasses.dataclass(frozen=True)
class PathModel:
    """An ordered sequence of per-link affine phases.

    ``flatten()`` composes the phases into the single ``(a, b)`` the
    MG-WFBP DP consumes: ``a = sum(a_l)``, ``b = sum(b_l)`` (each phase's
    ``b`` is already per full-message byte).  For the two-level ICI+DCN
    case this is bit-identical to :meth:`HierarchicalModel.flat` — pinned
    by regression test — so every flat-model consumer keeps producing the
    same plans.  The path view additionally exposes per-link structure:
    which links a collective occupies, and a per-phase refit surface
    (:func:`fit_path` / :func:`blend_path`) so each link's startup and
    bandwidth can be corrected from that link's own telemetry.
    """

    phases: tuple[PathPhase, ...]
    name: str = "path"

    def __post_init__(self):
        object.__setattr__(self, "phases", tuple(self.phases))
        if not self.phases:
            raise ValueError("a path needs >= 1 phase")

    # -- flat (a, b) view -------------------------------------------------

    @property
    def a(self) -> float:
        acc = 0.0
        for p in self.phases:
            acc += p.a
        return acc

    @property
    def b(self) -> float:
        acc = 0.0
        for p in self.phases:
            acc += p.b
        return acc

    def flatten(self) -> AllReduceModel:
        """The flat affine model the planner DP consumes."""
        return AllReduceModel(self.a, self.b, self.name)

    def time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    # -- per-link view ----------------------------------------------------

    @property
    def links(self) -> tuple[str, ...]:
        """Links in phase order, deduplicated."""
        seen: list[str] = []
        for p in self.phases:
            if p.link not in seen:
                seen.append(p.link)
        return tuple(seen)

    def phases_on(self, link: str) -> tuple[PathPhase, ...]:
        return tuple(p for p in self.phases if p.link == link)

    def link_bytes(self, nbytes: float) -> dict[str, float]:
        """Per-link bytes one collective of ``nbytes`` moves."""
        out: dict[str, float] = {}
        for p in self.phases:
            out[p.link] = out.get(p.link, 0.0) + p.link_bytes(nbytes)
        return out

    def scaled(self, factor: float) -> "PathModel":
        return PathModel(tuple(
            PathPhase(p.link, p.a * factor, p.b * factor, p.shard_fraction)
            for p in self.phases), self.name)

    def with_phases(self, phases: Sequence[PathPhase]) -> "PathModel":
        return PathModel(tuple(phases), self.name)


def single_path(model: AllReduceModel, link: str = "net") -> PathModel:
    """Wrap a flat (a, b) model as a one-phase path on ``link``."""
    return PathModel((PathPhase(link, model.a, model.b),), model.name)


def as_linear(model) -> AllReduceModel:
    """Normalize a cost model to the flat (a, b) view the DP consumes.

    Accepts an :class:`AllReduceModel` (returned as-is — the flat case is
    bit-identical to pre-PathModel behavior), a :class:`PathModel` or
    anything else exposing ``flatten()`` (flattened), or a
    :class:`HierarchicalModel` (via ``flat()``).  Objects with none of
    those pass through and must expose ``a``/``b``/``time`` themselves.
    """
    if isinstance(model, AllReduceModel):
        return model
    if isinstance(model, HierarchicalModel):
        return model.flat()
    if hasattr(model, "flatten"):
        return model.flatten()
    return model


def fit_path(base: PathModel,
             link_samples: Mapping[str, Sequence[tuple[int, float]]],
             samples: Sequence[tuple[int, float]] = ()) -> PathModel:
    """Per-phase refit of a path from per-link (nbytes, occupancy) samples.

    For each link with samples spanning >= 2 distinct sizes the link's
    observed occupancy is least-squares fit to ``a_l + b_l * M`` (M the
    FULL message size — the shard dilution lands in the fitted ``b_l``
    exactly as the base path encodes it).  Rank-deficient links fall back
    to stretch-scaling the base phase by the mean observed/predicted
    ratio.  Links with no samples at all keep their base phase, unless
    whole-collective ``samples`` are provided — then the whole path is
    stretch-scaled like the flat :func:`repro.core.planner.effective_model`
    degenerate case.

    A link that appears in several phases is refit as an aggregate and the
    correction distributed over its phases as a common stretch.
    """
    by_link: dict[str, list[tuple[float, float]]] = {}
    for link, pairs in link_samples.items():
        good = [(float(n), float(t)) for n, t in pairs if n > 0]
        if good:
            by_link[link] = good
    if not by_link:
        # no per-link telemetry: whole-collective stretch (flat fallback)
        sized = [(float(n), float(t)) for n, t in samples if n > 0]
        stretches = [t / self_t for n, t in sized
                     if (self_t := base.time(n)) > 0]
        if not stretches:
            return base
        return base.scaled(sum(stretches) / len(stretches))

    new_phases = list(base.phases)
    for link in base.links:
        pairs = by_link.get(link)
        if not pairs:
            continue
        idxs = [i for i, p in enumerate(base.phases) if p.link == link]
        agg_a = sum(base.phases[i].a for i in idxs)
        agg_b = sum(base.phases[i].b for i in idxs)
        if len({n for n, _ in pairs}) >= 2:
            fitted = fit([n for n, _ in pairs], [t for _, t in pairs],
                         f"effective:{link}")
            fa, fb = fitted.a, fitted.b
        else:
            agg = AllReduceModel(agg_a, agg_b, link)
            stretches = [t / agg.time(n) for n, t in pairs
                         if agg.time(n) > 0]
            if not stretches:
                continue
            s = sum(stretches) / len(stretches)
            fa, fb = agg_a * s, agg_b * s
        if len(idxs) == 1:
            i = idxs[0]
            p = base.phases[i]
            new_phases[i] = PathPhase(link, fa, fb, p.shard_fraction)
        else:
            # distribute the aggregate correction proportionally
            ra = fa / agg_a if agg_a > 0 else 0.0
            rb = fb / agg_b if agg_b > 0 else 0.0
            for j, i in enumerate(idxs):
                p = base.phases[i]
                a_i = p.a * ra if agg_a > 0 else (fa if j == 0 else 0.0)
                b_i = p.b * rb if agg_b > 0 else (fb if j == 0 else 0.0)
                new_phases[i] = PathPhase(link, a_i, b_i, p.shard_fraction)
    return base.with_phases(new_phases)


def blend_path(old: PathModel, new: PathModel, weight: float) -> PathModel:
    """Per-phase damped update (the path analogue of :func:`blend`)."""
    if not 0.0 <= weight <= 1.0:
        raise ValueError(f"blend weight must be in [0, 1], got {weight}")
    if len(old.phases) != len(new.phases) or \
            any(o.link != n.link for o, n in zip(old.phases, new.phases)):
        raise ValueError(
            f"cannot blend paths with different structure: "
            f"{[p.link for p in old.phases]} vs "
            f"{[p.link for p in new.phases]}")
    return PathModel(tuple(
        PathPhase(o.link, o.a * (1 - weight) + n.a * weight,
                  o.b * (1 - weight) + n.b * weight, o.shard_fraction)
        for o, n in zip(old.phases, new.phases)), new.name)


# ---------------------------------------------------------------------------
# Table 2: (a, b) per collective algorithm.
# ---------------------------------------------------------------------------

def _log2(n: int) -> float:
    if n < 1:
        raise ValueError(f"need >= 1 workers, got {n}")
    return math.log2(n)


def binary_tree(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Binary tree all-reduce [Rabenseifner'04]."""
    lg = _log2(n)
    return AllReduceModel(2 * alpha * lg, (2 * beta + gamma) * lg, "binary_tree")


def recursive_doubling(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    lg = _log2(n)
    return AllReduceModel(alpha * lg, (beta + gamma) * lg, "recursive_doubling")


def recursive_halving_doubling(n: int, alpha: float, beta: float,
                               gamma: float) -> AllReduceModel:
    lg = _log2(n)
    b = 2 * beta - (2 * beta + gamma) / n + gamma
    return AllReduceModel(2 * alpha * lg, b, "recursive_halving_doubling")


def double_binary_trees(n: int, alpha: float, beta: float,
                        gamma: float) -> AllReduceModel:
    """Double binary trees [Sanders'09] — NCCL >= 2.4 default at scale."""
    lg = _log2(n)
    return AllReduceModel(2 * alpha * lg, beta + gamma, "double_binary_trees")


def ring(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Ring all-reduce — bandwidth optimal, latency linear in N."""
    if n == 1:
        return AllReduceModel(0.0, 0.0, "ring")
    b = 2 * (n - 1) / n * beta + (n - 1) / n * gamma
    return AllReduceModel(2 * (n - 1) * alpha, b, "ring")


ALGORITHMS = {
    "binary_tree": binary_tree,
    "recursive_doubling": recursive_doubling,
    "recursive_halving_doubling": recursive_halving_doubling,
    "double_binary_trees": double_binary_trees,
    "ring": ring,
}


def make_model(algorithm: str, n: int, alpha: float, beta: float,
               gamma: float = 0.0) -> AllReduceModel:
    try:
        fn = ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(
            f"unknown all-reduce algorithm {algorithm!r}; "
            f"choose from {sorted(ALGORITHMS)}") from None
    return fn(n, alpha, beta, gamma)


# ---------------------------------------------------------------------------
# Model fitting (paper Fig. 4: measure all-reduce time vs message size, fit
# the linear model by least squares).
# ---------------------------------------------------------------------------

def fit(sizes_bytes: Sequence[float], times_s: Sequence[float],
        name: str = "fitted") -> AllReduceModel:
    """Least-squares fit of T(M) = a + b*M from measurements.

    Negative intercepts (possible with noisy small-size samples) are clamped
    to zero since a < 0 is non-physical and breaks the merge logic.
    """
    sizes = np.asarray(sizes_bytes, dtype=np.float64)
    times = np.asarray(times_s, dtype=np.float64)
    if sizes.shape != times.shape or sizes.ndim != 1 or sizes.size < 2:
        raise ValueError("need >= 2 paired (size, time) samples")
    A = np.stack([np.ones_like(sizes), sizes], axis=1)
    (a, b), *_ = np.linalg.lstsq(A, times, rcond=None)
    return AllReduceModel(max(float(a), 0.0), max(float(b), 0.0), name)


# ---------------------------------------------------------------------------
# TPU-specific models.
# ---------------------------------------------------------------------------

def tpu_ici_ring(axis_size: int, *, bw_per_link: float = ICI_BW_PER_LINK,
                 alpha: float = ICI_ALPHA, bidirectional: bool = True,
                 gamma: float = 0.0) -> AllReduceModel:
    """Ring all-reduce over one ICI mesh axis.

    A TPU torus axis provides one link per direction; the bidirectional ring
    all-reduce streams both directions, doubling effective bandwidth.
    """
    eff_bw = bw_per_link * (2.0 if bidirectional else 1.0)
    m = ring(axis_size, alpha, 1.0 / eff_bw, gamma)
    return AllReduceModel(m.a, m.b, "tpu_ici_ring")


def tpu_dcn(pods: int, *, bw: float = DCN_BW, alpha: float = DCN_ALPHA,
            gamma: float = 0.0) -> AllReduceModel:
    """Cross-pod (DCN) all-reduce: high-latency, lower-bandwidth level."""
    m = ring(pods, alpha, 1.0 / bw, gamma)
    return AllReduceModel(m.a, m.b, "tpu_dcn")


@dataclasses.dataclass(frozen=True)
class HierarchicalModel:
    """Two-level all-reduce: reduce-scatter intra-pod, all-reduce across
    pods on the 1/intra_size shard, all-gather intra-pod.

    Still linear in M, so it exposes the same (a, b) interface — this is what
    lets the *unmodified* MG-WFBP planner consume multi-pod topologies, which
    is our beyond-paper extension (the paper assumes a flat single-level
    model).
    """

    intra: AllReduceModel       # ICI level (cost of full all-reduce intra)
    inter: AllReduceModel       # DCN level
    intra_size: int             # chips per pod participating in level 1

    def path(self, ici_link: str = "ici", dcn_link: str = "dcn"
             ) -> PathModel:
        """The per-link decomposition this two-level model composes.

        ICI leg: RS + AG each cost ~half of a full all-reduce's bandwidth
        term but pay the full startup; DCN leg: all-reduce on the
        1/intra_size shard — its per-full-message-byte cost is the level
        model's ``b`` diluted by the shard, and only that fraction of the
        bytes crosses the link.  ``flat()``/``a``/``b`` derive from this
        path (one source of truth), bit-identical to the pre-PathModel
        formulas ``a = intra.a + inter.a``,
        ``b = intra.b + inter.b / intra_size``.
        """
        shard = max(self.intra_size, 1)
        return PathModel((
            PathPhase(ici_link, self.intra.a, self.intra.b),
            PathPhase(dcn_link, self.inter.a, self.inter.b / shard,
                      1.0 / shard),
        ), "hierarchical")

    @functools.cached_property
    def _default_path(self) -> PathModel:
        # cached: a/b/time are called per bucket per iteration in the
        # closed forms, and rebuilding the path there would put two
        # object constructions in that hot loop
        return self.path()

    @property
    def a(self) -> float:
        return self._default_path.a

    @property
    def b(self) -> float:
        return self._default_path.b

    @property
    def name(self) -> str:  # pragma: no cover - trivial
        return "hierarchical"

    def time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    def flat(self) -> AllReduceModel:
        """Collapse to a flat linear model for the planner."""
        return self._default_path.flatten()


def production_comm_model(mesh_shape: Sequence[int],
                          mesh_axis_names: Sequence[str],
                          dp_axes: Sequence[str] = ("pod", "data"),
                          algorithm: str = "ring") -> AllReduceModel:
    """Build the gradient all-reduce cost model for a production mesh.

    Single-pod meshes use the ICI model over the data axis; multi-pod meshes
    compose ICI (data axis) with DCN (pod axis) hierarchically.
    """
    dims = dict(zip(mesh_axis_names, mesh_shape))
    data = dims.get("data", 1)
    pods = dims.get("pod", 1)
    if "data" not in dp_axes:
        data = 1
    if "pod" not in dp_axes:
        pods = 1
    intra = tpu_ici_ring(data) if data > 1 else AllReduceModel(0.0, 0.0, "noop")
    if pods <= 1:
        return AllReduceModel(intra.a, intra.b, "tpu_ici_ring")
    inter = tpu_dcn(pods)
    return HierarchicalModel(intra=intra, inter=inter, intra_size=data).flat()
