"""Bucket assembly: gradient pytrees <-> flat merged buffers.

The paper's §5.3 pre-allocates one contiguous buffer per merged-gradient
group and copies each member tensor into it so a single all-reduce covers
the whole group.  Here a bucket is materialized by flattening member arrays
and concatenating (optionally through the ``bucket_pack`` Pallas kernel);
after the collective the buffer is split back into the original shapes.

Ordering: gradients are communicated in *backward production order* — the
reverse of the forward parameter-creation order.  Models expose their
parameters as a pytree; ``backward_order`` derives a deterministic tensor
ordering from the tree paths, and model configs may override it with an
explicit ordering when the pytree layout does not match execution order
(e.g. scan-stacked layers, handled by ``expand_stacked``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import MergePlan, TensorSpec


@dataclasses.dataclass(frozen=True)
class LeafMeta:
    """Metadata for one gradient leaf in backward order."""

    path: str
    shape: tuple[int, ...]
    dtype: Any
    size: int           # elements
    nbytes: int


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def leaves_in_backward_order(tree) -> list[tuple[str, Any]]:
    """(path, leaf) pairs, reversed forward order.

    ``jax.tree_util.tree_flatten_with_path`` is deterministic (sorted dict
    keys / tuple order); model param trees are built so that this order
    matches forward creation order, hence the reversal yields backward
    order.  Layer stacks built with ``lax.scan`` keep a leading layer axis;
    they are still one leaf here and are expanded by the planner via
    ``expand_stacked`` when per-layer granularity is wanted.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), v) for p, v in reversed(flat)]


def leaf_metadata(tree) -> list[LeafMeta]:
    out = []
    for path, leaf in leaves_in_backward_order(tree):
        shape = tuple(leaf.shape)
        dtype = leaf.dtype
        size = int(np.prod(shape)) if shape else 1
        out.append(LeafMeta(path, shape, dtype, size,
                            size * jnp.dtype(dtype).itemsize))
    return out


def tensor_specs(tree, t_b_fn: Callable[[LeafMeta], float]) -> list[TensorSpec]:
    """Build planner inputs from a parameter pytree and a timing model."""
    return [TensorSpec(m.path, m.nbytes, t_b_fn(m)) for m in leaf_metadata(tree)]


# ---------------------------------------------------------------------------
# Pack / unpack.
#
# Two buffer layouts share one contract:
#   * plain (``use_kernel=False``): leaves concatenated back to back;
#   * slot-aligned (``use_kernel=True``): each leaf occupies a TILE-aligned
#     slot (zero-padded tail), the layout the bucket_pack Pallas kernel
#     emits.  Pack and unpack must agree on ``use_kernel`` — the aligned
#     total is ``packed_elems(metas, aligned=True)``.
# ---------------------------------------------------------------------------

def slot_elems(size: int, aligned: bool = False) -> int:
    """Elements a leaf of ``size`` occupies in the packed buffer."""
    if not aligned:
        return size
    from repro.kernels.bucket_pack.kernel import TILE
    return size + ((-size) % TILE)


def packed_elems(metas: Sequence[LeafMeta], aligned: bool = False) -> int:
    """Total packed-buffer elements for a bucket under either layout."""
    return sum(slot_elems(m.size, aligned) for m in metas)


def pack(leaves: Sequence[jax.Array], dtype=None,
         use_kernel: bool = False) -> jax.Array:
    """Concatenate leaves into one flat buffer (paper §5.3 merged buffer)."""
    if not leaves:
        raise ValueError("empty bucket")
    dtype = dtype or jnp.result_type(*[l.dtype for l in leaves])
    if use_kernel:
        from repro.kernels.bucket_pack import ops as pack_ops
        return pack_ops.pack(list(leaves), dtype)
    flats = [l.reshape(-1).astype(dtype) for l in leaves]
    return jnp.concatenate(flats) if len(flats) > 1 else flats[0]


def unpack(buf: jax.Array, metas: Sequence[LeafMeta],
           use_kernel: bool = False) -> list[jax.Array]:
    """Split a flat buffer back into the bucket's member tensors."""
    expected = packed_elems(metas, aligned=use_kernel)
    if expected != buf.shape[0]:
        raise ValueError(f"buffer has {buf.shape[0]} elements, "
                         f"metas describe {expected}")
    if use_kernel:
        from repro.kernels.bucket_pack import ops as pack_ops
        return pack_ops.unpack(buf, [m.shape for m in metas],
                               [m.dtype for m in metas])
    out, off = [], 0
    for m in metas:
        out.append(jax.lax.dynamic_slice_in_dim(buf, off, m.size)
                   .reshape(m.shape).astype(m.dtype))
        off += m.size
    return out


def apply_bucketed(tree, plan: MergePlan,
                   collective: Callable[[jax.Array], jax.Array],
                   comm_dtype=None, use_kernel: bool = False):
    """Apply ``collective`` to each merged bucket of a gradient pytree.

    This is the generic engine used for all-reduce (psum), reduce-scatter,
    and compressed variants; the collective sees exactly one flat buffer per
    bucket, in plan order (backward order), mirroring the paper's pipeline.
    Returns a new pytree of the same structure.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [_path_str(p) for p, _ in flat]
    leaves = [v for _, v in flat]
    metas = leaf_metadata(tree)                      # backward order
    if plan.num_tensors != len(metas):
        raise ValueError(
            f"plan covers {plan.num_tensors} tensors but tree has {len(metas)}")
    # backward-order index -> forward flat index
    fwd_index = {path: i for i, path in enumerate(paths)}
    new_leaves: list[Any] = [None] * len(leaves)
    for bucket in plan.buckets:
        bmetas = [metas[i] for i in bucket]
        arrs = [leaves[fwd_index[m.path]] for m in bmetas]
        orig_dtype = arrs[0].dtype
        buf = pack(arrs, dtype=comm_dtype or orig_dtype, use_kernel=use_kernel)
        buf = collective(buf)
        wire_metas = [dataclasses.replace(mm, dtype=buf.dtype) for mm in bmetas]
        for m, arr in zip(bmetas, unpack(buf, wire_metas,
                                         use_kernel=use_kernel)):
            new_leaves[fwd_index[m.path]] = arr.astype(m.dtype)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# Scan-stacked parameter expansion.
# ---------------------------------------------------------------------------

def expand_stacked(metas: Sequence[LeafMeta], stacked_axis_name: str = "layers",
                   num_layers: int | None = None) -> list[LeafMeta]:
    """Expand scan-stacked leaves (leading layer axis) into per-layer metas.

    For planning purposes a stacked leaf of shape (L, ...) is L logical
    tensors produced at different times during the backward scan.  The
    packed representation stays stacked at runtime; only the *planner* sees
    the expansion (granularity of the cost model), so plans computed on the
    expanded view are mapped back by ``contract_plan``.
    """
    out = []
    for m in metas:
        if num_layers and m.shape and m.shape[0] == num_layers and stacked_axis_name in m.path:
            per = m.size // m.shape[0]
            for l in range(m.shape[0]):
                out.append(LeafMeta(f"{m.path}[{l}]", m.shape[1:], m.dtype,
                                    per, per * jnp.dtype(m.dtype).itemsize))
        else:
            out.append(m)
    return out
