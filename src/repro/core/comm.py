"""Bucketed collectives: the runtime half of MG-WFBP.

Inside a ``shard_map`` whose data-parallel axes are manual, gradients arrive
as *unreduced* per-shard values.  These helpers reduce them bucket-by-bucket
according to a :class:`MergePlan`:

* ``bucketed_allreduce``      — one ``lax.psum`` per bucket (paper semantics).
* ``bucketed_reduce_scatter`` / ``bucketed_allgather`` — ZeRO-1 variant: the
  plan drives merged reduce-scatters of gradients and merged all-gathers of
  updated parameters (beyond-paper).
* ``hierarchical_allreduce``  — two-level pod-aware reduction: RS intra-pod,
  AR across pods on the shard, AG intra-pod (beyond-paper; motivated by the
  paper's own observation that merging pays where the startup term is big —
  the DCN pod axis is exactly that).
* Compression hooks: cast-to-bf16-on-the-wire with fp32 accumulation
  (paper §8 lists gradient compression as future work).

All functions are pure and jit-safe; XLA's latency-hiding scheduler overlaps
the per-bucket collectives with any remaining compute they do not depend on,
which is the TPU-native realization of the paper's C++ comm thread.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import bucketer
from repro.core.planner import MergePlan

AxisNames = str | Sequence[str]


def axis_size(name: str) -> int:
    """Static mesh-axis size inside a collective context, on any JAX.

    New JAX has ``jax.lax.axis_size``; on old JAX ``psum(1, name)`` of a
    concrete value constant-folds to the same static int.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)


def replicated_shard(buf: jax.Array, axis_name: str) -> jax.Array:
    """This member's tile of a dim-0-even value replicated over ``axis_name``.

    Only reached on new JAX: the sole caller is the ZeRO-1 step, which
    ``build_train_step`` degrades to the replicated optimizer on old JAX
    (its merged all-gather cannot compile inside an old partial-auto
    shard_map anyway).
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    sz = buf.shape[0] // n
    return jax.lax.dynamic_slice_in_dim(buf, idx * sz, sz)


def _mean_scale(axis_names: AxisNames) -> Callable[[jax.Array], jax.Array]:
    def scale(x):
        n = 1
        names = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
        for a in names:
            n *= axis_size(a)
        return x / n
    return scale


def _wire_cast(buf: jax.Array, wire_dtype) -> tuple[jax.Array, Callable]:
    """Optionally compress the on-wire representation (e.g. bf16)."""
    if wire_dtype is None or buf.dtype == jnp.dtype(wire_dtype):
        return buf, lambda y: y
    orig = buf.dtype
    return buf.astype(wire_dtype), lambda y: y.astype(orig)


def _cpu_promotes(dtype) -> bool:
    """XLA:CPU's AllReducePromotion crashes on 16-bit reductions with
    partial replica groups; promote around the collective on CPU only
    (TPU, the target, reduces bf16 natively)."""
    dt = jnp.dtype(dtype)
    return (jax.default_backend() == "cpu" and dt.itemsize < 4
            and jnp.issubdtype(dt, jnp.floating))


def safe_psum(x, axis_names: AxisNames):
    """psum with the CPU 16-bit promotion workaround (pytree-ok)."""
    def one(v):
        if _cpu_promotes(v.dtype):
            return jax.lax.psum(v.astype(jnp.float32), axis_names
                                ).astype(v.dtype)
        return jax.lax.psum(v, axis_names)
    return jax.tree.map(one, x)


def safe_psum_scatter(buf: jax.Array, axis_name: str, **kw) -> jax.Array:
    if _cpu_promotes(buf.dtype):
        return jax.lax.psum_scatter(buf.astype(jnp.float32), axis_name,
                                    **kw).astype(buf.dtype)
    return jax.lax.psum_scatter(buf, axis_name, **kw)


def safe_all_gather(x: jax.Array, axis_name: str, *, axis: int) -> jax.Array:
    """Tiled all_gather whose VJP routes through the CPU-safe
    reduce-scatter (the FSDP gradient path: gather fwd, scatter bwd)."""

    @jax.custom_vjp
    def ag(v):
        return jax.lax.all_gather(v, axis_name, axis=axis, tiled=True)

    def fwd(v):
        return ag(v), None

    def bwd(_, g):
        return (safe_psum_scatter(g, axis_name, scatter_dimension=axis,
                                  tiled=True),)

    ag.defvjp(fwd, bwd)
    return ag(x)


def bucketed_allreduce(grads, plan: MergePlan, axis_names: AxisNames,
                       *, mean: bool = True, wire_dtype=None,
                       mode: str = "fused", use_kernel: bool = False):
    """All-reduce a gradient pytree bucket-by-bucket (MG-WFBP runtime).

    ``mode="fused"`` (default, TPU-native): each bucket is ONE variadic
    ``lax.psum`` — XLA emits a single all-reduce op with one operand per
    member tensor, so the startup cost is amortized exactly as the paper's
    merged buffer does on MPI, **without** the pack copy and without
    disturbing each leaf's tensor-parallel sharding.

    ``mode="packed"`` (paper-faithful §5.3): members are copied into one
    contiguous buffer (optionally via the bucket_pack Pallas kernel) and a
    single 1-D all-reduce runs.  Costs a pack/unpack round trip and a TP
    gather for model-sharded leaves — kept for baseline comparison and for
    interconnects that require contiguous buffers.
    """
    scale = _mean_scale(axis_names)

    if mode == "packed":
        def collective(buf):
            buf, restore = _wire_cast(buf, wire_dtype)
            buf = safe_psum(buf, axis_names)
            buf = restore(buf)
            return scale(buf) if mean else buf

        return bucketer.apply_bucketed(grads, plan, collective,
                                       use_kernel=use_kernel)

    # fused: one variadic psum per (bucket, dtype) — XLA requires uniform
    # operand element types per all-reduce
    metas = bucketer.leaf_metadata(grads)
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    paths = [bucketer._path_str(p) for p, _ in flat]
    fwd_index = {p: i for i, p in enumerate(paths)}
    leaves = [v for _, v in flat]
    new_leaves = list(leaves)
    for bucket in plan.buckets:
        idxs = [fwd_index[metas[i].path] for i in bucket]
        casted, restores = [], []
        for i in idxs:
            c, r = _wire_cast(leaves[i], wire_dtype)
            casted.append(c)
            restores.append(r)
        by_dtype: dict = {}
        for pos, c in enumerate(casted):
            by_dtype.setdefault(jnp.dtype(c.dtype), []).append(pos)
        for dt, poss in sorted(by_dtype.items(), key=lambda kv: str(kv[0])):
            ops = [casted[p] for p in poss]
            promote = _cpu_promotes(dt)
            if promote:
                ops = [o.astype(jnp.float32) for o in ops]
            reduced = jax.lax.psum(tuple(ops), axis_names)
            if promote:
                reduced = tuple(r.astype(dt) for r in reduced)
            for p, red in zip(poss, reduced):
                out = restores[p](red)
                new_leaves[idxs[p]] = scale(out) if mean else out
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def hierarchical_allreduce(grads, plan: MergePlan, *, intra_axis: str = "data",
                           inter_axis: str = "pod", mean: bool = True,
                           wire_dtype=None, mode: str = "fused",
                           use_kernel: bool = False):
    """Two-level pod-aware all-reduce per bucket.

    reduce-scatter over the intra-pod axis, all-reduce the 1/intra shard over
    the pod axis, all-gather intra-pod.  Moves 1/intra of the bytes over the
    slow inter-pod links compared to a flat all-reduce over (pod, data).

    ``mode="fused"``: psum over intra is variadic per bucket (sharding
    preserving); the pod-level reduce then runs on the intra-reduced values
    — a latency-optimal schedule when the pod axis dominates startup.
    """
    if mode == "fused":
        # intra-level merged psum, then pod-level merged psum per bucket.
        out = bucketed_allreduce(grads, plan, intra_axis, mean=mean,
                                 wire_dtype=wire_dtype, mode="fused")
        return bucketed_allreduce(out, plan, inter_axis, mean=mean,
                                  wire_dtype=wire_dtype, mode="fused")

    scale = _mean_scale((intra_axis, inter_axis))

    def collective(buf):
        buf, restore = _wire_cast(buf, wire_dtype)
        n = axis_size(intra_axis)
        pad = (-buf.shape[0]) % n
        if pad:
            buf = jnp.pad(buf, (0, pad))
        shard = safe_psum_scatter(buf, intra_axis, scatter_dimension=0,
                                  tiled=True)
        shard = safe_psum(shard, inter_axis)
        full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
        if pad:
            full = full[: full.shape[0] - pad]
        full = restore(full)
        return scale(full) if mean else full

    return bucketer.apply_bucketed(grads, plan, collective,
                                   use_kernel=use_kernel)


# ---------------------------------------------------------------------------
# ZeRO-1: merged reduce-scatter of grads + merged all-gather of params.
# ---------------------------------------------------------------------------

def bucket_shard_size(nelems: int, n: int) -> int:
    """Padded per-shard element count for a tiled collective over n shards."""
    return (nelems + n - 1) // n


def bucketed_reduce_scatter(grads, plan: MergePlan, axis_name: str,
                            *, mean: bool = True, wire_dtype=None,
                            use_kernel: bool = False):
    """Reduce-scatter each bucket over the DP axis; returns, per bucket, this
    shard's slice (list aligned with plan.buckets) plus unpack metadata.

    The caller runs the optimizer on the shard and then calls
    ``bucketed_allgather`` — both collectives enjoy the same merged-message
    startup saving that motivates MG-WFBP for plain all-reduce.
    ``use_kernel`` selects the bucket_pack Pallas layout (TILE-aligned
    slots); the caller's param repack and the all-gather must use the same
    flag or shard offsets disagree.
    """
    metas = bucketer.leaf_metadata(grads)
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    by_path = {bucketer._path_str(p): v for p, v in flat}
    n = axis_size(axis_name)
    shards, bucket_metas = [], []
    for bucket in plan.buckets:
        bmetas = [metas[i] for i in bucket]
        buf = bucketer.pack([by_path[m.path] for m in bmetas],
                            use_kernel=use_kernel)
        buf, restore = _wire_cast(buf, wire_dtype)
        pad = (-buf.shape[0]) % n
        if pad:
            buf = jnp.pad(buf, (0, pad))
        shard = safe_psum_scatter(buf, axis_name, scatter_dimension=0,
                                  tiled=True)
        shard = restore(shard)
        if mean:
            shard = shard / n
        shards.append(shard)
        bucket_metas.append(bmetas)
    return shards, bucket_metas


def bucketed_allgather(shards: Sequence[jax.Array],
                       bucket_metas: Sequence[Sequence[bucketer.LeafMeta]],
                       treedef_like, axis_name: str,
                       *, use_kernel: bool = False):
    """Gather updated parameter shards back into the full pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(treedef_like)
    paths = [bucketer._path_str(p) for p, _ in flat]
    fwd_index = {p: i for i, p in enumerate(paths)}
    new_leaves = [None] * len(flat)
    for shard, bmetas in zip(shards, bucket_metas):
        full = jax.lax.all_gather(shard, axis_name, axis=0, tiled=True)
        total = bucketer.packed_elems(bmetas, aligned=use_kernel)
        full = full[:total]
        for m, arr in zip(bmetas, bucketer.unpack(full, bmetas,
                                                  use_kernel=use_kernel)):
            new_leaves[fwd_index[m.path]] = arr
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def collective_bytes_of_plan(plan: MergePlan, specs_bytes: Sequence[int]) -> list[int]:
    """Per-bucket wire bytes (diagnostics for EXPERIMENTS.md)."""
    out = []
    for bucket in plan.buckets:
        out.append(sum(specs_bytes[i] for i in bucket))
    return out
