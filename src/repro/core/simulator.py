"""Closed-form pipeline simulator for bucketed WFBP communication (Eqs. 6-8).

Given per-tensor backward times, a merge plan, and an all-reduce cost model,
replay the timeline:

  * gradients become ready in backward order at prefix sums of ``t_b``;
  * bucket k's all-reduce starts at ``max(ready(last tensor of k),
    end of bucket k-1's all-reduce)``                        (paper Eq. 7)
  * iteration time = t_f + final all-reduce end              (paper Eq. 8)

This is the FAST PATH: O(L) per evaluation, which is what the planner
property tests and the O(L^2)-evaluation planners need.  For anything the
closed form cannot express — heterogeneous/straggling workers, link
contention between collectives or jobs, elastic resizes — use the
event-driven engine in ``repro.sim``; :func:`cross_validate` checks the
two agree exactly on their shared (homogeneous, single-job) domain.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.cost_model import AllReduceModel
from repro.core.planner import MergePlan, TensorSpec


@dataclasses.dataclass(frozen=True)
class BucketEvent:
    bucket: int
    nbytes: int
    ready: float        # when the bucket's last gradient is produced
    start: float        # when its all-reduce starts
    end: float          # when its all-reduce completes


@dataclasses.dataclass(frozen=True)
class SimResult:
    t_f: float                 # forward time (input)
    t_b_total: float           # total backward compute
    comm_total: float          # sum of bucket all-reduce times
    comm_end: float            # timestamp (backward origin) of last comm end
    t_iter: float              # t_f + comm_end  (== paper Eq. 8)
    t_c_no: float              # non-overlapped communication (bottleneck)
    events: tuple[BucketEvent, ...]

    @property
    def overlap_ratio(self) -> float:
        """Fraction of communication hidden under computation."""
        if self.comm_total <= 0:
            return 1.0
        return max(0.0, 1.0 - self.t_c_no / self.comm_total)


def simulate(specs: Sequence[TensorSpec], plan: MergePlan,
             model: AllReduceModel, t_f: float = 0.0) -> SimResult:
    if plan.num_tensors != len(specs):
        raise ValueError(
            f"plan covers {plan.num_tensors} tensors, specs has {len(specs)}")
    ready, acc = [], 0.0
    for s in specs:
        acc += s.t_b
        ready.append(acc)
    t_b_total = acc

    events: list[BucketEvent] = []
    prev_end = 0.0
    comm_total = 0.0
    for k, bucket in enumerate(plan.buckets):
        nbytes = sum(specs[i].nbytes for i in bucket)
        r = ready[bucket[-1]]
        start = max(r, prev_end)
        dur = model.time(nbytes)
        end = start + dur
        comm_total += dur
        events.append(BucketEvent(k, nbytes, r, start, end))
        prev_end = end
    comm_end = prev_end if events else t_b_total
    comm_end = max(comm_end, t_b_total)
    return SimResult(
        t_f=t_f,
        t_b_total=t_b_total,
        comm_total=comm_total,
        comm_end=comm_end,
        t_iter=t_f + comm_end,
        t_c_no=comm_end - t_b_total,
        events=tuple(events),
    )


def spec_arrays(specs: Sequence[TensorSpec]
                ) -> tuple[np.ndarray, np.ndarray]:
    """Prefix sums over the backward order: the two arrays every closed
    form is built from.

    Returns ``(prefix_bytes, prefix_t)`` where ``prefix_bytes`` has L+1
    entries (``prefix_bytes[j]`` = bytes of tensors 0..j-1, exact in
    float64 for any realistic model size) and ``prefix_t[j]`` is the
    ready time of tensor j relative to backward start.  Compute these
    ONCE per profile and derive every plan's bucket arrays from them
    (:func:`bucket_arrays`) instead of re-walking the specs per grid
    point — the hoist behind ``repro.sim.sweep`` and the fleet backend.
    """
    nbytes = np.array([s.nbytes for s in specs], dtype=np.float64)
    t_b = np.array([s.t_b for s in specs], dtype=np.float64)
    prefix_bytes = np.zeros(len(specs) + 1, dtype=np.float64)
    np.cumsum(nbytes, out=prefix_bytes[1:])
    return prefix_bytes, np.cumsum(t_b)


def bucket_arrays(prefix_bytes: np.ndarray, prefix_t: np.ndarray,
                  plan: MergePlan) -> tuple[np.ndarray, np.ndarray]:
    """Per-bucket ``(nbytes, ready offset)`` arrays from hoisted prefixes.

    Buckets are contiguous index ranges (``MergePlan`` invariant), so a
    bucket's byte total is a prefix-sum difference — exact, because the
    prefixes are integer-valued float64 — and its ready offset is the
    prefix ready time of its last tensor.  O(num_buckets) numpy instead
    of O(L) Python per evaluation.
    """
    if not plan.buckets:
        return np.zeros(0), np.zeros(0)
    first = np.array([b[0] for b in plan.buckets], dtype=np.intp)
    last = np.array([b[-1] for b in plan.buckets], dtype=np.intp)
    return prefix_bytes[last + 1] - prefix_bytes[first], prefix_t[last]


def batched_comm_end(bucket_t: np.ndarray, ready: np.ndarray,
                     bwd_end: np.ndarray | float = 0.0) -> np.ndarray:
    """Vectorized Eq. 7/8 recurrence over arbitrary leading grid axes.

    ``ready[..., k]`` is when bucket k's last gradient is produced (relative
    to iteration start) and ``bucket_t[..., k]`` its all-reduce duration;
    both broadcast over the leading axes (scenario grid: worker counts ×
    jitter seeds × bandwidth levels).  Returns the iteration end —
    ``max(last comm end, bwd_end)`` — per grid point.  This is the batched
    fast path behind ``repro.sim.sweep``: one numpy pass per *bucket*
    instead of one event loop per *scenario*, exact on the closed form's
    domain (sequential comm, no link contention).
    """
    bucket_t, ready = np.broadcast_arrays(
        np.asarray(bucket_t, dtype=np.float64),
        np.asarray(ready, dtype=np.float64))
    end = np.zeros(bucket_t.shape[:-1], dtype=np.float64)
    for k in range(bucket_t.shape[-1]):
        end = np.maximum(end, ready[..., k]) + bucket_t[..., k]
    return np.maximum(end, np.asarray(bwd_end, dtype=np.float64))


def cross_validate(specs: Sequence[TensorSpec], plan: MergePlan,
                   model: AllReduceModel, t_f: float = 0.0,
                   atol: float = 1e-9, **engine_kwargs) -> SimResult:
    """Run the closed form AND the event-driven engine; assert they agree.

    The engine (repro.sim) reaches the same iteration time through
    independent mechanics — a priority-queue event loop over compute
    streams and link resources — so agreement within ``atol`` (default
    1e-9 s) is strong evidence both are implementing Eqs. 6-8.
    """
    from repro.sim import event_driven_t_iter  # local: sim depends on core

    res = simulate(specs, plan, model, t_f)
    t_engine = event_driven_t_iter(specs, plan, model, t_f, **engine_kwargs)
    if abs(res.t_iter - t_engine) > atol:
        raise AssertionError(
            f"closed form t_iter={res.t_iter!r} != engine {t_engine!r} "
            f"(|diff|={abs(res.t_iter - t_engine):.3e} > atol={atol})")
    return res


def speedup(specs: Sequence[TensorSpec], plan: MergePlan,
            model: AllReduceModel, t_f: float, n_workers: int) -> float:
    """Throughput speedup over single-worker SGD (paper Eqs. 4-5).

    S(N) = N / (1 + t_c_no / (t_f + t_b)) with the non-overlapped
    communication as the only added cost.
    """
    res = simulate(specs, plan, model, t_f)
    denom = t_f + res.t_b_total
    if denom <= 0:
        raise ValueError("need positive compute time")
    return n_workers / (1.0 + res.t_c_no / denom)


def compare_strategies(specs: Sequence[TensorSpec], model: AllReduceModel,
                       t_f: float = 0.0,
                       strategies: Sequence[str] = (
                           "wfbp", "single", "mgwfbp", "dp_optimal"),
                       ) -> dict[str, SimResult]:
    """Run every strategy through the simulator (the paper's comparison)."""
    from repro.core.planner import make_plan

    return {
        s: simulate(specs, make_plan(s, specs, model), model, t_f)
        for s in strategies
    }
