"""MG-WFBP core: cost models, merge planners, pipeline simulator, bucketed
collectives.  This package is the paper's contribution."""

from repro.core.cost_model import (
    AllReduceModel,
    HierarchicalModel,
    PathModel,
    PathPhase,
    as_linear,
    blend_path,
    fit_path,
    make_model,
    fit,
    production_comm_model,
    single_path,
    PAPER_CLUSTERS,
)
from repro.core.planner import (
    TensorSpec,
    MergePlan,
    make_plan,
    plan_wfbp,
    plan_single,
    plan_fixed_size,
    plan_mgwfbp,
    plan_dp_optimal,
    plan_brute_force,
    plan_contention_aware,
    replan,
)
from repro.core.coplanner import (
    CoJob,
    CoObservation,
    CoPlanResult,
    CoPlanner,
    CoRound,
    JobObservation,
    coplan,
    coplan_incremental,
)
from repro.core.simulator import (simulate, speedup, compare_strategies,
                                  cross_validate, SimResult)
from repro.core import bucketer, comm, profiler

__all__ = [
    "AllReduceModel", "HierarchicalModel", "PathModel", "PathPhase",
    "as_linear", "blend_path", "fit_path", "single_path",
    "make_model", "fit", "production_comm_model", "PAPER_CLUSTERS",
    "TensorSpec", "MergePlan", "make_plan", "plan_wfbp", "plan_single",
    "plan_fixed_size", "plan_mgwfbp", "plan_dp_optimal", "plan_brute_force",
    "plan_contention_aware", "replan",
    "CoJob", "CoObservation", "CoPlanResult", "CoPlanner", "CoRound",
    "JobObservation", "coplan", "coplan_incremental",
    "simulate", "speedup", "compare_strategies", "cross_validate",
    "SimResult",
    "bucketer", "comm", "profiler",
]
