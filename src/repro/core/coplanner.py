"""Multi-job co-planning: shared-link best-response over merge plans.

MG-WFBP's optimal bucketing (and the DP fast path in ``planner.py``)
assumes an exclusive link.  PR 2's contention fixpoint
(:func:`repro.core.planner.plan_contention_aware`) corrected the *model*
— plan, simulate in the contended environment, refit the effective
(a, b), replan — but only for ONE job against a frozen neighbour.  On a
shared fabric every job is somebody's neighbour: each job's plan shapes
the contention every other job observes (the coupled task-graph view of
S-SGD, arXiv:1805.03812), and the *shape* of that contention depends on
each job's iteration schedule (DeAR's bursty reduce-scatter phases vs
BSP's end-of-iteration wall, arXiv:2302.12445).

:class:`CoPlanner` closes the loop jointly, with **alternating**
best-response rounds — each round sweeps the jobs, and each sub-step:

1. **simulates all jobs together** — one ``evaluate(plans)`` call
   returns, per job, the achieved iteration time and the observed
   per-collective (nbytes, occupancy) samples, plus the joint makespan.
   The engine's per-flow-owner link accounting attributes every sample
   to the job that owns the collective: job A's sample set never
   contains job B's collectives or background ``Burst`` flows, while
   each sample's *duration* deliberately embeds the processor-sharing
   stretch those neighbours cause — that stretch is exactly what the
   effective model must capture;
2. **refits the sub-step's job's effective (a, b)** from its own samples
   (:func:`planner.effective_model`), damped against the previous
   estimate — each job is refit once per sweep, at its own sub-step,
   from the freshest observation, so the damping strength means the
   same thing for one job as for ten.  In *shared-effective-model* mode
   the fit instead pools the samples of every job sharing the link into
   ONE contended model per link;
3. **replans that job** with its incremental :class:`~planner.Planner`
   under its refit model, so the next sub-step's simulation shows the
   remaining jobs their neighbour's *new* plan (simultaneous replanning
   instead oscillates between mirror assignments on symmetric fleets);
   each job's per-round prediction uses its own schedule's closed form
   (``Schedule.predict_t_iter``), so a pipelined job and a local-SGD job
   are each optimized for the discipline they actually run;
4. **accept/reject**: the incumbent is the best *observed* assignment by
   joint makespan; iteration stops when a full sweep leaves the
   assignment fixed, the assignment revisits (deterministic cycle), or
   ``max_rounds`` sweeps are exhausted — at most
   ``len(jobs) * max_rounds`` evaluated response rounds.

The result can never be worse than the seed assignment on the evaluated
environment: the round-0 exclusive-link plans and every caller-supplied
seed plan are in the evaluated candidate set, and the best observed
assignment wins (the same guarantee the single-job fixpoint made, lifted
to the joint objective).

``plan_contention_aware`` is now literally the N=1 special case: it
builds one :class:`CoJob`, adapts its ``evaluate`` to the joint
signature, and converts the result back through
:meth:`CoPlanResult.fixpoint` — reproducing the PR-2 loop round for
round (pinned by tests/test_coplanner.py and the pre-existing fixpoint
tests).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core import cost_model
from repro.core.cost_model import AllReduceModel
from repro.obs.metrics import REGISTRY
from repro.obs.recorder import EventRecord, plan_fingerprint
from repro.core.planner import (FixpointResult, FixpointRound, MergePlan,
                                Planner, TensorSpec, effective_model)


@dataclasses.dataclass(frozen=True)
class CoJob:
    """The planning-side view of one job sharing the fabric.

    ``model`` is the job's exclusive-link cost model (its round-0 plan and
    the baseline its effective model is refit from); ``schedule`` (a
    ``repro.sim.schedules.Schedule`` or None for BSP) selects the closed
    form used for per-round predictions; ``seed_plans`` are static
    baselines the co-plan must not lose to (evaluated with every other
    job on its round-0 plan); ``links`` names the fabric links this job's
    collectives occupy — used only by shared-effective-model mode to pool
    occupancy samples per link (leave empty to keep the job on per-job
    refit).
    """

    name: str
    specs: tuple[TensorSpec, ...]
    model: AllReduceModel           # or a cost_model.PathModel
    t_f: float = 0.0
    schedule: object | None = None
    seed_plans: tuple[MergePlan, ...] = ()
    links: tuple[str, ...] = ()

    def predict(self, plan: MergePlan, model: AllReduceModel) -> float:
        """Closed-form iteration time under this job's schedule."""
        if self.schedule is not None:
            return self.schedule.predict_t_iter(self.specs, plan, model,
                                                self.t_f)
        from repro.core.simulator import simulate   # local import: no cycle
        return simulate(self.specs, plan, model, self.t_f).t_iter


@dataclasses.dataclass(frozen=True)
class JobObservation:
    """What one job experienced in one joint evaluation.

    ``samples`` — the refit input — are this job's own collectives only
    (the engine attributes each to its flow owner); their durations
    embed the contention stretch the neighbours cause.  ``link_bytes`` /
    ``link_busy`` carry the job's per-link byte/bandwidth-share totals
    (background bursts accounted separately, never here) — diagnostic
    telemetry for callers and round records, not a refit input.
    """

    t_iter: float                                # achieved s/iteration
    samples: tuple[tuple[int, float], ...]       # (nbytes, occupancy s)
    link_bytes: tuple[tuple[str, float], ...] = ()
    link_busy: tuple[tuple[str, float], ...] = ()
    # per-link (nbytes, occupancy) samples — ``samples`` decomposed leg by
    # leg (the engine's ``JobResult.link_samples``).  THE refit input for
    # jobs carrying a PathModel: each link's (a_l, b_l) is corrected from
    # its own column, and shared-model mode pools columns per physical
    # link across jobs.
    link_samples: tuple[tuple[str, tuple[tuple[int, float], ...]], ...] = ()


@dataclasses.dataclass(frozen=True)
class CoObservation:
    """One joint simulation of every job under a candidate assignment."""

    makespan: float                              # joint objective (s)
    jobs: Mapping[str, JobObservation]


# evaluate(plans: job name -> candidate MergePlan) -> CoObservation
CoEvaluate = Callable[[Mapping[str, MergePlan]], CoObservation]


def _models_compatible(candidate, base) -> bool:
    """True iff ``candidate`` can stand in for ``base`` as a job's
    effective model: refit dispatches on the model KIND (per-link for
    :class:`~repro.core.cost_model.PathModel`, whole-collective
    otherwise) and per-phase blending needs identical link structure, so
    a warm-start model of the wrong shape would silently change the
    refit mode mid-fleet."""
    cand_path = isinstance(candidate, cost_model.PathModel)
    base_path = isinstance(base, cost_model.PathModel)
    if cand_path != base_path:
        return False
    if cand_path:
        return [p.link for p in candidate.phases] == \
            [p.link for p in base.phases]
    return True


@dataclasses.dataclass(frozen=True)
class CoRound:
    """One evaluated assignment: a seed candidate or a best-response round."""

    kind: str                                    # "seed" | "response"
    plans: Mapping[str, MergePlan]
    models: Mapping[str, AllReduceModel]         # effective, AFTER refit
    planned_under: Mapping[str, AllReduceModel]  # models the plans came from
    observation: CoObservation
    predicted: Mapping[str, float]               # per-job closed form

    @property
    def makespan(self) -> float:
        return self.observation.makespan


@dataclasses.dataclass(frozen=True)
class CoPlanResult:
    plans: Mapping[str, MergePlan]               # best observed assignment
    models: Mapping[str, AllReduceModel]         # that round's refit models
    rounds: tuple[CoRound, ...]
    converged: bool                              # fixed point or exact cycle
    best_round: int

    @property
    def makespan(self) -> float:
        return self.rounds[self.best_round].observation.makespan

    def observed_t(self, name: str) -> float:
        """Best round's achieved iteration time for one job."""
        return self.rounds[self.best_round].observation.jobs[name].t_iter

    def fixpoint(self, name: str) -> FixpointResult:
        """Single-job view of the joint run, in the PR-2 fixpoint types.

        With one job this is a lossless conversion (the joint makespan IS
        the job's observed time); with several it narrates the co-plan
        from one job's perspective — note ``best_round`` is still chosen
        by the JOINT objective.
        """
        rounds = tuple(
            FixpointRound(plan=r.plans[name], model=r.models[name],
                          observed_t=r.observation.jobs[name].t_iter,
                          predicted_t=r.predicted[name],
                          planned_under=r.planned_under[name])
            for r in self.rounds)
        return FixpointResult(plan=self.plans[name], model=self.models[name],
                              rounds=rounds, converged=self.converged,
                              best_round=self.best_round)


class CoPlanner:
    """Alternating best-response co-planner over N jobs on shared links.

    ``evaluate`` simulates (or measures) ALL jobs together under a
    candidate assignment; evaluations are deterministic in the assignment
    and cached, so seed candidates and fixed-point revisits never pay for
    the same simulation twice.  An evaluator may additionally expose
    ``batch(assignments) -> [CoObservation]`` — every uncached candidate
    of a round is then scored in ONE call
    (``repro.sim.fleet.FleetEvaluator`` turns a 100-job seed round into
    a single jitted device pass); results are identical to the
    sequential path by the determinism contract.

    ``response_mode`` selects the best-response inner loop:

    * ``"sweep"`` (default) — alternating Gauss-Seidel: one evaluation,
      one refit, one incremental replan per job per sub-step.  N=1 is
      round-for-round the PR-2 fixpoint (pinned by tests) — this mode's
      behavior is frozen.
    * ``"batched"`` — one *fleet-batched* round: refit EVERY job from
      the incumbent observation, generate every job's response plan in
      ONE batched-DP call (``repro.sim.fleet.plan_cases``), then score
      all single-change candidates plus the all-changes response through
      ONE batched evaluation, and move to the best candidate.  Same
      seed-candidate guarantee, same incumbent-keeps-best acceptance;
      the per-round device-call count stops scaling with fleet size
      (plan + score each one call), which is the fleet-scale regime —
      at N=1 the two modes coincide step for step.

    ``damping`` weights each refit against
    the previous effective model (suppressing the two-cycle oscillation a
    full-step update can fall into — now per job).  With
    ``shared_model=True`` jobs that declare their ``links`` are refit
    from the *aggregate* per-link sample pool instead of their own
    samples only: one contended :class:`AllReduceModel` per link, the
    right regime when co-located jobs run comparable collectives and the
    per-job sample streams are too thin to fit alone.
    """

    def __init__(self, jobs: Sequence[CoJob], evaluate: CoEvaluate, *,
                 max_rounds: int = 5, damping: float = 0.5,
                 shared_model: bool = False,
                 response_mode: str = "sweep",
                 initial_plans: Mapping[str, MergePlan] | None = None,
                 initial_models: Mapping[str, AllReduceModel] | None = None,
                 recorder=None):
        if not 0.0 < damping <= 1.0:
            raise ValueError(f"damping must be in (0, 1], got {damping}")
        if max_rounds < 1:
            raise ValueError("need >= 1 round")
        if response_mode not in ("sweep", "batched"):
            raise ValueError(f"unknown response_mode {response_mode!r}")
        names = [j.name for j in jobs]
        if not names:
            raise ValueError("need >= 1 job")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate job names: {names}")
        by_name = {j.name: j for j in jobs}
        for name, p in (initial_plans or {}).items():
            if name not in by_name:
                raise ValueError(f"initial plan for unknown job {name!r}")
            if p.num_tensors != len(by_name[name].specs):
                raise ValueError(
                    f"initial plan for {name!r} covers {p.num_tensors} "
                    f"tensors, job has {len(by_name[name].specs)}")
        for name, m in (initial_models or {}).items():
            if name not in by_name:
                raise ValueError(f"initial model for unknown job {name!r}")
            if not _models_compatible(m, by_name[name].model):
                raise ValueError(
                    f"initial model for {name!r} is incompatible with "
                    f"the job's model kind (flat vs per-link path, or "
                    f"mismatched phase links)")
        self.jobs = tuple(jobs)
        self.evaluate = evaluate
        self.max_rounds = max_rounds
        self.damping = damping
        self.shared_model = shared_model
        self.response_mode = response_mode
        self.initial_plans = dict(initial_plans or {})
        self.initial_models = dict(initial_models or {})
        # optional repro.obs.recorder.FlightRecorder for round events
        self.recorder = recorder

    # -- internals -------------------------------------------------------

    def _key(self, plans: Mapping[str, MergePlan]) -> tuple:
        return tuple((j.name, plans[j.name].buckets) for j in self.jobs)

    def _link_pool(self, obs: CoObservation, job: CoJob,
                   links: Sequence[str]) -> dict[str, list[tuple[int,
                                                                 float]]]:
        """Per-link refit samples for ``job``: its own leg-by-leg
        telemetry, or — in shared-model mode — the aggregate pool of
        every job's samples on each physical link.  Unlike
        whole-collective durations, a per-link sample is a clean
        observation of THAT link no matter which other links the donor's
        path crosses, so pooling needs no same-shape gating."""
        pool: dict[str, list[tuple[int, float]]] = {l: [] for l in links}
        donors = self.jobs if self.shared_model else (job,)
        for j in donors:
            for link, pairs in obs.jobs[j.name].link_samples:
                if link in pool:
                    pool[link].extend(pairs)
        return pool

    def _batch_replan(self, models: Mapping[str, AllReduceModel]
                      ) -> dict[str, MergePlan]:
        """Every job's best-response plan under its current effective
        model, via ONE batched-DP kernel call (step 3 at fleet scale)."""
        from repro.sim import fleet as fleet_backend   # local: no cycle
        planned = fleet_backend.plan_batched(
            [(j.specs, models[j.name]) for j in self.jobs])
        return {j.name: p for j, p in zip(self.jobs, planned)}

    def _refit(self, obs: CoObservation, eff: dict[str, AllReduceModel],
               job: CoJob) -> None:
        """One damped effective-model update for ``job`` (step 2).

        Exactly one job per sub-step: refitting the whole fleet at every
        sub-step would blend each model N times per sweep, silently
        scaling the damping strength with fleet size.

        A job carrying a :class:`~repro.core.cost_model.PathModel` is
        refit PER LINK: each phase's (a_l, b_l) from that link's own
        occupancy samples (``JobObservation.link_samples``), pooled per
        physical link across jobs in shared-model mode — which is what
        makes ``shared_model=True`` work on hierarchical fleets, where
        the old whole-collective pooling had to be disabled."""
        cur = eff[job.name]
        jo = obs.jobs[job.name]
        if isinstance(cur, cost_model.PathModel):
            pool = self._link_pool(obs, job, cur.links)
            fitted = cost_model.fit_path(cur, pool, jo.samples)
            eff[job.name] = cost_model.blend_path(cur, fitted,
                                                  self.damping)
            return
        samples: Sequence[tuple[int, float]] = jo.samples
        if self.shared_model and len(job.links) == 1:
            # flat models fit whole-collective durations, so donors must
            # live on exactly the same single link: a multi-link job's
            # durations embed time on its OTHER links and would bias the
            # per-link fit
            pooled: list[tuple[int, float]] = []
            for j in self.jobs:
                if j.links == job.links:
                    pooled.extend(obs.jobs[j.name].samples)
            if pooled:
                samples = pooled
        fitted = effective_model(samples, eff[job.name])
        eff[job.name] = cost_model.blend(eff[job.name], fitted,
                                         self.damping)

    # -- the loop --------------------------------------------------------

    def run(self) -> CoPlanResult:
        jobs = self.jobs
        if self.response_mode == "batched":
            # round-0 exclusive-link plans for the whole fleet in one
            # batched-DP call — no per-job Python planner at all
            planners: dict[str, Planner] = {}
            plans = self._batch_replan({j.name: j.model for j in jobs})
        else:
            planners = {j.name: Planner(list(j.specs), j.model)
                        for j in jobs}
            plans = {j.name: planners[j.name].plan() for j in jobs}
        eff = {j.name: j.model for j in jobs}
        # warm start (job churn): the incumbent assignment/models replace
        # the exclusive-link round-0 state, so the loop re-enters best
        # response from where the fleet already is instead of from
        # scratch; jobs without an incumbent entry (arrivals) keep their
        # fresh exclusive-link plan.
        eff.update(self.initial_models)
        plans.update(self.initial_plans)
        rounds: list[CoRound] = []
        best_round = 0
        cache: dict[tuple, CoObservation] = {}

        def observe(assignment: Mapping[str, MergePlan]) -> CoObservation:
            k = self._key(assignment)
            if k not in cache:
                cache[k] = self.evaluate(dict(assignment))
            return cache[k]

        def observe_many(assignments: Sequence[Mapping[str, MergePlan]]
                         ) -> None:
            """Prefill the cache for a batch of candidate assignments.

            When the evaluator exposes a ``batch`` method (e.g.
            ``repro.sim.fleet.FleetEvaluator``) every uncached candidate
            of the round is scored in ONE call — a single jitted device
            pass at fleet scale — otherwise this degrades to the
            sequential loop, in the same order the candidates are later
            pushed (identical evaluate() call sequence)."""
            todo: list[dict[str, MergePlan]] = []
            keys: list[tuple] = []
            for a in assignments:
                k = self._key(a)
                if k not in cache and k not in keys:
                    keys.append(k)
                    todo.append(dict(a))
            if not todo:
                return
            batch_fn = getattr(self.evaluate, "batch", None)
            if batch_fn is None or len(todo) == 1:
                for a in todo:
                    observe(a)
                return
            observations = batch_fn(todo)
            if len(observations) != len(todo):
                raise ValueError(
                    f"evaluate.batch returned {len(observations)} "
                    f"observations for {len(todo)} assignments")
            for k, o in zip(keys, observations):
                cache[k] = o
            REGISTRY.counter(
                "coplanner_batched_evals_total",
                "candidate assignments scored through a batched "
                "evaluate() instead of one-by-one").inc(len(todo))
            REGISTRY.histogram(
                "coplanner_batched_eval_size",
                "candidate assignments per batched evaluate() call — "
                "the planning-stage amortization factor").observe(
                    len(todo))

        def predict_all(assignment: Mapping[str, MergePlan]
                        ) -> dict[str, float]:
            return {j.name: j.predict(assignment[j.name], eff[j.name])
                    for j in jobs}

        def push(round_: CoRound) -> None:
            nonlocal best_round
            rounds.append(round_)
            if round_.makespan < rounds[best_round].makespan:
                best_round = len(rounds) - 1
            REGISTRY.counter("coplanner_rounds_total",
                             "co-planning rounds evaluated, by kind").inc(
                                 kind=round_.kind)
            if self.recorder is not None:
                self.recorder.record(EventRecord(
                    kind="coplan_round", time=float(len(rounds) - 1),
                    source="coplanner",
                    args={"round_kind": round_.kind,
                          "makespan": round_.makespan,
                          "plans": {name: plan_fingerprint(p)
                                    for name, p in round_.plans.items()}}))

        # seed candidates: each job's static baselines against everyone
        # else's round-0 plan — evaluate only, no refit.
        pushed: set[tuple] = set()
        seed_assignments: list[dict[str, MergePlan]] = []
        for j in jobs:
            for sp in j.seed_plans:
                assignment = {**plans, j.name: sp}
                pushed.add(self._key(assignment))
                seed_assignments.append(assignment)
        # ... plus the fully independent assignment (every job on its
        # primary seed plan at once): that is the "each job planned alone
        # under the exclusive-link model" baseline the co-plan must not
        # lose to.  Skipped when it coincides with an assignment already
        # in the candidate set (always true for N=1, which keeps the
        # single-job delegation round-for-round identical to PR 2).
        combined = {j.name: (j.seed_plans[0] if j.seed_plans
                             else plans[j.name]) for j in jobs}
        if self._key(combined) not in pushed | {self._key(plans)}:
            seed_assignments.append(combined)
        observe_many(seed_assignments)     # one batched call when possible
        for assignment in seed_assignments:
            push(CoRound("seed", assignment, dict(eff), dict(eff),
                         observe(assignment), predict_all(assignment)))

        # Alternating (Gauss-Seidel) best response: each round sweeps the
        # jobs in order, and each sub-step simulates ALL jobs together
        # under the current assignment, refits every job's effective
        # (a, b) from its own telemetry, then replans ONE job — so the
        # next job responds to its neighbour's *new* plan, not the
        # round-start snapshot.  (A job's DP replan depends only on its
        # own effective model; the neighbours' plans enter through the
        # observation that shapes the refit, which is why the
        # re-observation between sub-steps is what makes the response
        # "alternating".)  Simultaneous replanning instead oscillates
        # between mirror assignments on symmetric fleets and never finds
        # the asymmetric equilibria that actually minimize the joint
        # makespan.  With one job, a sweep IS the PR-2 fixpoint round.
        seen: set[tuple] = {self._key(plans)}
        converged = False
        if self.response_mode == "batched":
            # Fleet-batched (Jacobi-flavored) best response: per round,
            # ONE joint observation refits every job, ONE batched-DP call
            # plans every job's response, and ONE batched evaluation
            # scores all single-change candidates plus the all-changes
            # response — then the loop moves to the best-scoring
            # candidate.  Moving one device call per round (instead of
            # one evaluation per job sub-step) is what makes 100-job
            # rounds serve online; the single-change candidates keep the
            # alternating flavor (the winner is usually one job's
            # response to the incumbent), while the all-changes candidate
            # catches the fleets where simultaneous movement wins.
            for _ in range(self.max_rounds):
                obs = observe(plans)                   # cached on re-entry
                planned_under = dict(eff)
                for j in jobs:
                    self._refit(obs, eff, j)
                push(CoRound("response", dict(plans), dict(eff),
                             planned_under, obs, predict_all(plans)))
                responses = self._batch_replan(eff)
                moved = [j.name for j in jobs
                         if responses[j.name].buckets
                         != plans[j.name].buckets]
                if not moved:
                    converged = True                   # joint fixed point
                    break
                candidates = [{**plans, n: responses[n]} for n in moved]
                if len(moved) > 1:
                    candidates.append(
                        {**plans, **{n: responses[n] for n in moved}})
                observe_many(candidates)   # one batched evaluation
                for cand in candidates:
                    push(CoRound("response", cand, dict(eff), dict(eff),
                                 observe(cand), predict_all(cand)))
                plans = dict(min(candidates,
                                 key=lambda c: observe(c).makespan))
                k = self._key(plans)
                if k in seen:
                    converged = True       # deterministic cycle
                    break
                seen.add(k)
            best = rounds[best_round]
            return CoPlanResult(plans=dict(best.plans),
                                models=dict(best.models),
                                rounds=tuple(rounds), converged=converged,
                                best_round=best_round)

        for _ in range(self.max_rounds):
            changed = False
            for j in jobs:
                planned_under = dict(eff)
                obs = observe(plans)                   # step 1 (cached if
                self._refit(obs, eff, j)               # unchanged); step 2
                push(CoRound("response", dict(plans), dict(eff),
                             planned_under, obs, predict_all(plans)))
                new_plan = planners[j.name].replan(eff[j.name])  # step 3
                if new_plan.buckets == plans[j.name].buckets:
                    continue
                changed = True
                plans = {**plans, j.name: new_plan}
                if self._key(plans) in seen:
                    # exact assignment revisit: the deterministic loop can
                    # only cycle from here — stop, keep the best observed.
                    converged = True
                    break
                seen.add(self._key(plans))
            else:
                if not changed:
                    converged = True                   # joint fixed point
                    break
                continue
            break

        best = rounds[best_round]
        return CoPlanResult(plans=dict(best.plans), models=dict(best.models),
                            rounds=tuple(rounds), converged=converged,
                            best_round=best_round)


def coplan(jobs: Sequence[CoJob], evaluate: CoEvaluate, *,
           max_rounds: int = 5, damping: float = 0.5,
           shared_model: bool = False,
           response_mode: str = "sweep") -> CoPlanResult:
    """One-shot convenience wrapper around :class:`CoPlanner`."""
    return CoPlanner(jobs, evaluate, max_rounds=max_rounds, damping=damping,
                     shared_model=shared_model,
                     response_mode=response_mode).run()


def coplan_incremental(incumbent: CoPlanResult, jobs: Sequence[CoJob],
                       evaluate: CoEvaluate, *, max_rounds: int = 5,
                       damping: float = 0.5,
                       shared_model: bool = False,
                       response_mode: str = "sweep") -> CoPlanResult:
    """Re-plan after job arrival/departure from an incumbent co-plan.

    ``jobs`` is the NEW fleet (arrivals included, departures dropped);
    ``evaluate`` must simulate that fleet.  Surviving jobs re-enter the
    best-response loop from the incumbent's plans and effective models —
    so an arrival perturbs a converged assignment instead of discarding
    it, and a departure leaves the survivors' fitted contention models as
    the starting estimate (too pessimistic now, corrected by the first
    refit sweep).  Arrivals have no incumbent entry and start from their
    exclusive-link plan, exactly like round 0 of a fresh co-plan.  The
    incumbent's plans for surviving jobs become round-0 candidates, so
    the result can never be worse than keeping the incumbent assignment
    on the new fleet — the churn analogue of the seed guarantee.
    """
    names = {j.name: j for j in jobs}
    plans = {n: p for n, p in incumbent.plans.items()
             if n in names and p.num_tensors == len(names[n].specs)}
    # carry a survivor's fitted model forward only when it matches the
    # new job's model kind/structure — e.g. a flat incumbent cannot seed
    # a per-link path job without silently disabling its per-link refit
    models = {n: m for n, m in incumbent.models.items()
              if n in plans and _models_compatible(m, names[n].model)}
    return CoPlanner(jobs, evaluate, max_rounds=max_rounds,
                     damping=damping, shared_model=shared_model,
                     response_mode=response_mode,
                     initial_plans=plans, initial_models=models).run()
