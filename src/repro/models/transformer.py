"""Unified LM: one model class covering all 10 assigned architectures.

A model is a stack of *blocks*; ``ModelConfig.block_kind(i)`` names each
block's sequence mixer (attn / mamba / mlstm / slstm), its FFN (dense / moe
/ none) and its attention window.  Blocks are grouped into *stages*: a stage
is either a single unrolled block or a scanned repeat-group (period P
pattern × R repeats, params stacked on a leading R axis) — the
compile-time-tractable layout for 95-layer × 512-device dry-runs.

Entry points (all pure):
  * ``loss(params, batch)``                       — training objective
  * ``prefill(params, batch)``  -> (logits, cache)
  * ``decode_step(params, cache, tokens, pos)``   — one token w/ KV cache
  * ``init / init_cache / param_pspecs / input_specs``

KV caches: full-attention layers cache [B, max_len, Hkv, Dh] (rope applied
at write time); sliding-window layers use a ring buffer of ``window`` slots
with a slot→position table, so gemma3's 5:1 local:global pattern caches
window×5/6 of the naive footprint.  Mamba/xLSTM blocks carry O(1) state.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import layers, mamba, moe, xlstm

AUX_LOSS_COEF = 0.01


@dataclasses.dataclass(frozen=True)
class StageDef:
    first_layer: int       # global index of the stage's first block
    period: int            # blocks per repeat group
    repeats: int           # scanned repeats (1 => unrolled single group)
    encoder: bool = False  # whisper encoder stage

    @property
    def scanned(self) -> bool:
        return self.repeats > 1


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


class LM:
    """Decoder-only (or encoder-decoder) language model."""

    def __init__(self, cfg: ModelConfig,
                 parallel: ParallelConfig = ParallelConfig()):
        self.cfg = cfg
        self.parallel = parallel

    # ------------------------------------------------------------------
    # Stage layout.
    # ------------------------------------------------------------------

    def stage_layout(self) -> list[StageDef]:
        cfg = self.cfg
        stages: list[StageDef] = []
        if cfg.enc_dec:
            if self.parallel.scan_layers and cfg.enc_layers > 1:
                stages.append(StageDef(0, 1, cfg.enc_layers, encoder=True))
            else:
                stages += [StageDef(i, 1, 1, encoder=True)
                           for i in range(cfg.enc_layers)]
        n, skip = cfg.num_layers, cfg.moe_skip_first
        stages += [StageDef(i, 1, 1) for i in range(skip)]
        if not self.parallel.scan_layers:
            stages += [StageDef(i, 1, 1) for i in range(skip, n)]
            return stages
        period = cfg.repeat_period()
        repeats = (n - skip) // period
        if repeats <= 1:
            stages += [StageDef(i, 1, 1) for i in range(skip, n)]
        else:
            stages.append(StageDef(skip, period, repeats))
        return stages

    def _block_kind(self, layer_idx: int, encoder: bool) -> dict:
        if encoder:
            return {"mixer": "attn", "ffn": "dense", "window": 0,
                    "causal": False, "cross": False}
        k = self.cfg.block_kind(layer_idx)
        k["causal"] = True
        k["cross"] = self.cfg.enc_dec
        return k

    # ------------------------------------------------------------------
    # Init.
    # ------------------------------------------------------------------

    def _block_init(self, key, kind: dict) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        d, hd = cfg.d_model, cfg.resolved_head_dim
        ks = jax.random.split(key, 6)
        p: dict[str, Any] = {}
        if kind["mixer"] == "attn":
            p["norm1"] = jnp.ones((d,), dt)
            p["attn"] = layers.attn_init(ks[0], d, cfg.num_heads,
                                         cfg.num_kv_heads, hd, dt,
                                         cfg.qkv_bias)
        elif kind["mixer"] == "mamba":
            p["norm1"] = jnp.ones((d,), dt)
            p["mamba"] = mamba.mamba_init(ks[0], cfg, dt)
        elif kind["mixer"] == "mlstm":
            p["norm1"] = jnp.ones((d,), dt)
            p["mlstm"] = xlstm.mlstm_init(ks[0], cfg, dt)
        elif kind["mixer"] == "slstm":
            p["norm1"] = jnp.ones((d,), dt)
            p["slstm"] = xlstm.slstm_init(ks[0], cfg, dt)
        if kind["cross"]:
            p["norm_x"] = jnp.ones((d,), dt)
            p["cross"] = layers.attn_init(ks[1], d, cfg.num_heads,
                                          cfg.num_kv_heads, hd, dt, False)
        if kind["ffn"] == "dense":
            p["norm2"] = jnp.ones((d,), dt)
            p["mlp"] = layers.mlp_init(ks[2], d, cfg.d_ff, cfg.act, dt)
        elif kind["ffn"] == "moe":
            p["norm2"] = jnp.ones((d,), dt)
            p["moe"] = moe.moe_init(ks[3], cfg, dt)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = iter(jax.random.split(key, 4 + len(self.stage_layout()) * 2))
        params: dict[str, Any] = {
            "embed": layers.embed_init(next(keys), cfg.vocab_size,
                                       cfg.d_model, dt),
        }
        stages = []
        for st in self.stage_layout():
            kinds = [self._block_kind(st.first_layer + j, st.encoder)
                     for j in range(st.period)]

            def group_init(k, kinds=kinds):
                gks = jax.random.split(k, len(kinds))
                return {f"blk{j:02d}": self._block_init(gks[j], kinds[j])
                        for j in range(len(kinds))}

            if st.scanned:
                stages.append(jax.vmap(group_init)(
                    jax.random.split(next(keys), st.repeats)))
            else:
                stages.append(group_init(next(keys)))
        params["stages"] = stages
        params["final_norm"] = jnp.ones((cfg.d_model,), dt)
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(next(keys), cfg.d_model,
                                                  cfg.vocab_size, dt)
        if cfg.enc_dec:
            params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        return params

    # ------------------------------------------------------------------
    # Block apply (shared by train / prefill / decode).
    # ------------------------------------------------------------------

    def _attn_train(self, bp, x, kind, rope, enc_out=None):
        cfg, par = self.cfg, self.parallel
        hd = cfg.resolved_head_dim
        h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
        q, k, v = layers.qkv_proj(bp["attn"], h, cfg.num_heads,
                                  cfg.num_kv_heads, hd)
        if kind["mixer"] == "attn" and not kind.get("no_rope"):
            cos, sin = rope
            q, k = layers.apply_rope(q, cos, sin), layers.apply_rope(k, cos, sin)
        o = layers.attention(q, k, v, causal=kind["causal"],
                             window=kind["window"], chunk=par.attn_chunk)
        x = x + layers.out_proj(bp["attn"], o)
        if kind["cross"] and enc_out is not None:
            h = layers.rms_norm(x, bp["norm_x"], cfg.norm_eps)
            q, _, _ = layers.qkv_proj(bp["cross"], h, cfg.num_heads,
                                      cfg.num_kv_heads, hd)
            ke, ve = self._enc_kv(bp["cross"], enc_out)
            o = layers.attention(q, ke, ve, causal=False,
                                 chunk=par.attn_chunk)
            x = x + layers.out_proj(bp["cross"], o)
        return x, (k, v)

    def _enc_kv(self, ap, enc_out):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        b, s, _ = enc_out.shape
        ke = (enc_out @ ap["w_k"]).reshape(b, s, cfg.num_kv_heads, hd)
        ve = (enc_out @ ap["w_v"]).reshape(b, s, cfg.num_kv_heads, hd)
        return ke, ve

    def _block_train(self, bp, x, kind, rope, enc_out=None, collect=False):
        """One block forward.  With ``collect`` also returns the decode
        state the block would leave behind (prefill priming)."""
        cfg = self.cfg
        state: dict = {}
        if kind["mixer"] == "attn":
            x, kv = self._attn_train(bp, x, kind, rope, enc_out)
            if collect:
                state["kv"] = kv    # k already rope'd at its position
        elif kind["mixer"] == "mamba":
            h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
            out = mamba.mamba_apply(bp["mamba"], h, return_state=collect)
            if collect:
                out, state["mamba"] = out
            x = x + out
        elif kind["mixer"] == "mlstm":
            h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
            out = xlstm.mlstm_apply(bp["mlstm"], h, cfg, return_state=collect)
            if collect:
                out, state["mlstm"] = out
            x = x + out
        elif kind["mixer"] == "slstm":
            h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
            out = xlstm.slstm_apply(bp["slstm"], h, return_state=collect)
            if collect:
                out, state["slstm"] = out
            x = x + out
        x, aux = self._ffn_half(bp, x, kind)
        return x, aux, state

    # ------------------------------------------------------------------
    # Forward over stages (train / prefill).
    # ------------------------------------------------------------------

    def _run_stages(self, params, x, rope, enc_out=None, encoder=False,
                    collect_states=False):
        """Run all (matching) stages; returns (x, aux, states).

        ``states`` is a list parallel to the stage layout; each entry is a
        dict ``blkNN -> block state`` (stacked on a leading repeat axis for
        scanned stages), or None when not collecting / stage mismatched."""
        cfg, par = self.cfg, self.parallel
        aux_total = jnp.zeros((), jnp.float32)
        states: list[Any] = []

        for st, sp in zip(self.stage_layout(), params["stages"]):
            if st.encoder != encoder:
                states.append(None)
                continue
            kinds = [self._block_kind(st.first_layer + j, st.encoder)
                     for j in range(st.period)]

            def group_apply(gp, x, kinds=kinds):
                aux = jnp.zeros((), jnp.float32)
                st_out = {}
                for j, kind in enumerate(kinds):
                    x, a, bstate = self._block_train(
                        gp[f"blk{j:02d}"], x, kind, rope, enc_out,
                        collect=collect_states)
                    aux = aux + a
                    st_out[f"blk{j:02d}"] = bstate
                return x, aux, st_out

            alternating = (par.remat == "alternating" and st.scanned
                           and st.repeats % 2 == 0 and not collect_states)
            if par.remat == "block" or (par.remat == "alternating"
                                        and not alternating):
                group_apply = jax.checkpoint(group_apply, static_argnums=())

            unroll = st.scanned and layers.unroll_scans_here()
            if alternating:
                # remat every 2nd repeat-group: halves recompute FLOPs for
                # one group's worth of live internals (§Perf iteration)
                rematted = jax.checkpoint(group_apply, static_argnums=())

                if unroll:
                    for r in range(st.repeats // 2):
                        gp_a = jax.tree.map(lambda l, r=r: l[2 * r], sp)
                        gp_b = jax.tree.map(lambda l, r=r: l[2 * r + 1], sp)
                        x, a1, _ = rematted(gp_a, x)
                        x, a2, _ = group_apply(gp_b, x)
                        aux_total = aux_total + a1 + a2
                    states.append(None)
                    continue

                def scan_body2(carry, gp2):
                    x, aux = carry
                    gp_a = jax.tree.map(lambda l: l[0], gp2)
                    gp_b = jax.tree.map(lambda l: l[1], gp2)
                    x, a1, _ = rematted(gp_a, x)
                    x, a2, _ = group_apply(gp_b, x)
                    return (x, aux + a1 + a2), None
                sp2 = jax.tree.map(
                    lambda l: l.reshape((st.repeats // 2, 2) + l.shape[1:]),
                    sp)
                (x, aux_total), _ = jax.lax.scan(scan_body2, (x, aux_total),
                                                 sp2)
                states.append(None)
            elif st.scanned:
                if unroll:
                    collected = []
                    for r in range(st.repeats):
                        gp = jax.tree.map(lambda l, r=r: l[r], sp)
                        x, a, s = group_apply(gp, x)
                        aux_total = aux_total + a
                        collected.append(s)
                    if collect_states and collected:
                        states.append(jax.tree.map(
                            lambda *ls: jnp.stack(ls), *collected))
                    else:
                        states.append(None)
                    continue

                def scan_body(carry, gp):
                    x, aux = carry
                    x, a, s = group_apply(gp, x)
                    return (x, aux + a), s
                (x, aux_total), st_states = jax.lax.scan(
                    scan_body, (x, aux_total), sp)
                states.append(st_states if collect_states else None)
            else:
                x, a, st_states = group_apply(sp, x)
                aux_total = aux_total + a
                states.append(st_states if collect_states else None)
        return x, aux_total, states

    def _ffn_half(self, bp, x, kind):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if kind["ffn"] == "dense":
            h = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
            x = x + layers.mlp_apply(bp["mlp"], h, cfg.act)
        elif kind["ffn"] == "moe":
            h = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
            mo, aux = moe.moe_apply(bp["moe"], h, cfg,
                                    self.parallel.ep_axis,
                                    self.parallel)
            x = x + mo
        return x, aux

    # ------------------------------------------------------------------
    # Embedding / head.
    # ------------------------------------------------------------------

    def _embed(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens] * math.sqrt(cfg.d_model)
        x = x.astype(_dtype(cfg))
        if prefix_embeds is not None:
            p = prefix_embeds.shape[1]
            x = jnp.concatenate(
                [prefix_embeds.astype(x.dtype), x[:, p:]], axis=1)
        return x

    def _head(self, params, x):
        cfg = self.cfg
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = x @ w
        return layers.pshard(logits, None, None, "model")

    def _rope(self, positions):
        return layers.rope_angles(positions, self.cfg.resolved_head_dim,
                                  self.cfg.rope_theta)

    def _sinusoid(self, positions):
        """Sinusoidal positions for the enc-dec decoder (whisper uses a
        learned table capped at 448; sinusoidal removes the cap so the
        assigned 32k structural shapes lower — DESIGN.md §5)."""
        d = self.cfg.d_model
        half = d // 2
        freq = jnp.exp(-jnp.arange(half) * (jnp.log(10000.0) / (half - 1)))
        ang = positions.astype(jnp.float32)[:, None] * freq[None]
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1
                               ).astype(_dtype(self.cfg))

    # ------------------------------------------------------------------
    # Training loss.
    # ------------------------------------------------------------------

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        """batch: tokens [B,S] int32, labels [B,S] int32 (-1 = masked);
        optional prefix_embeds [B,P,d]; enc-dec adds enc_embeds [B,Se,d]."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        b, s = tokens.shape
        enc_out = None
        if cfg.enc_dec:
            enc = batch["enc_embeds"].astype(_dtype(cfg))
            rope_e = self._rope(jnp.arange(enc.shape[1]))
            enc, aux_e, _ = self._run_stages(params, enc, rope_e,
                                             encoder=True)
            enc_out = layers.rms_norm(enc, params["enc_norm"], cfg.norm_eps)
            x = self._embed(params, tokens)
            x = x + self._sinusoid(jnp.arange(s))[None]
        else:
            x = self._embed(params, tokens,
                            batch.get("prefix_embeds"))
        rope = self._rope(jnp.arange(s))
        x, aux, _ = self._run_stages(params, x, rope, enc_out=enc_out)
        logits = self._head(params, x)

        logits = logits.astype(jnp.float32)
        mask = (labels >= 0)
        safe = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = (logz - ll) * mask
        denom = jnp.maximum(mask.sum(), 1)
        loss = nll.sum() / denom
        total = loss + AUX_LOSS_COEF * aux
        return total, {"ce_loss": loss, "aux_loss": aux,
                       "tokens": denom.astype(jnp.float32)}

    # ------------------------------------------------------------------
    # KV cache.
    # ------------------------------------------------------------------

    def _cache_len(self, kind, max_len: int) -> int:
        w = kind["window"]
        return min(w, max_len) if w else max_len

    def _block_cache(self, kind, batch: int, max_len: int, enc_len: int) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        hd = cfg.resolved_head_dim
        c: dict[str, Any] = {}
        if kind["mixer"] == "attn":
            cl = self._cache_len(kind, max_len)
            c["k"] = jnp.zeros((batch, cl, cfg.num_kv_heads, hd), dt)
            c["v"] = jnp.zeros((batch, cl, cfg.num_kv_heads, hd), dt)
            c["slot_pos"] = jnp.full((cl,), -1, jnp.int32)
        elif kind["mixer"] == "mamba":
            c["mamba"] = mamba.mamba_cache_init(cfg, batch, dt)
        elif kind["mixer"] == "mlstm":
            c["mlstm"] = xlstm.mlstm_cache_init(cfg, batch)
        elif kind["mixer"] == "slstm":
            c["slstm"] = xlstm.slstm_cache_init(cfg, batch)
        if kind["cross"]:
            c["xk"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dt)
            c["xv"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dt)
        return c

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0):
        caches = []
        for st in self.stage_layout():
            if st.encoder:
                caches.append({})
                continue
            kinds = [self._block_kind(st.first_layer + j, False)
                     for j in range(st.period)]
            group = {f"blk{j:02d}": self._block_cache(k, batch, max_len,
                                                      enc_len)
                     for j, k in enumerate(kinds)}
            if st.scanned:
                group = jax.tree.map(
                    lambda l: jnp.broadcast_to(l, (st.repeats,) + l.shape),
                    group)
            caches.append(group)
        return caches

    # ------------------------------------------------------------------
    # Decode.
    # ------------------------------------------------------------------

    def _attn_decode(self, bp, cache, x, kind, pos):
        """x: [B,1,d].  Returns (x, new block cache)."""
        cfg, par = self.cfg, self.parallel
        hd = cfg.resolved_head_dim
        h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
        q, k, v = layers.qkv_proj(bp["attn"], h, cfg.num_heads,
                                  cfg.num_kv_heads, hd)
        cos, sin = self._rope(pos[None])
        q, k = layers.apply_rope(q, cos, sin), layers.apply_rope(k, cos, sin)
        cl = cache["k"].shape[1]
        slot = pos % cl if kind["window"] else jnp.minimum(pos, cl - 1)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        spos = cache["slot_pos"].at[slot].set(pos)
        # mask: valid slot, causal, window
        valid = (spos >= 0) & (spos <= pos)
        if kind["window"]:
            valid &= spos > pos - kind["window"]
        o = self._masked_decode_attend(q, kc, vc, valid)
        x = x + layers.out_proj(bp["attn"], o)
        newc = {"k": kc, "v": vc, "slot_pos": spos}
        if kind["cross"]:
            h = layers.rms_norm(x, bp["norm_x"], cfg.norm_eps)
            qx, _, _ = layers.qkv_proj(bp["cross"], h, cfg.num_heads,
                                       cfg.num_kv_heads, hd)
            o = layers.attention(qx, cache["xk"], cache["xv"], causal=False,
                                 chunk=par.attn_chunk)
            x = x + layers.out_proj(bp["cross"], o)
            newc["xk"], newc["xv"] = cache["xk"], cache["xv"]
        return x, newc

    @staticmethod
    def _masked_decode_attend(q, kc, vc, valid):
        """q: [B,1,Hq,D]; kc/vc: [B,CL,Hkv,D]; valid: [CL] bool."""
        b, _, hq, d = q.shape
        hkv = kc.shape[2]
        qg = q.reshape(b, 1, hkv, hq // hkv, d).astype(jnp.float32)
        qg = qg / math.sqrt(d)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, kc.astype(jnp.float32))
        logits = jnp.where(valid[None, None, None, None, :], logits,
                           layers.NEG_INF)
        m = logits.max(axis=-1, keepdims=True)
        p = jnp.exp(logits - m) * valid[None, None, None, None, :]
        w = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
        o = jnp.einsum("bkgst,btkd->bskgd", w, vc.astype(jnp.float32))
        return o.reshape(b, 1, hq, d).astype(q.dtype)

    def _block_decode(self, bp, cache, x, kind, pos):
        cfg = self.cfg
        if kind["mixer"] == "attn":
            x, newc = self._attn_decode(bp, cache, x, kind, pos)
        elif kind["mixer"] == "mamba":
            h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
            y, mc = mamba.mamba_decode_step(bp["mamba"], cache["mamba"], h)
            x, newc = x + y, {"mamba": mc}
        elif kind["mixer"] == "mlstm":
            h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
            y, mc = xlstm.mlstm_decode_step(bp["mlstm"], cache["mlstm"], h, cfg)
            x, newc = x + y, {"mlstm": mc}
        elif kind["mixer"] == "slstm":
            h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
            y, mc = xlstm.slstm_decode_step(bp["slstm"], cache["slstm"], h)
            x, newc = x + y, {"slstm": mc}
        x, _ = self._ffn_half(bp, x, kind)
        return x, newc

    def decode_step(self, params, cache, tokens, pos):
        """tokens: [B,1] int32; pos: scalar int32 (next position index)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if cfg.enc_dec:
            x = x + self._sinusoid(pos[None])[None]
        new_caches = []
        for st, sp, sc in zip(self.stage_layout(), params["stages"], cache):
            if st.encoder:
                new_caches.append(sc)
                continue
            kinds = [self._block_kind(st.first_layer + j, False)
                     for j in range(st.period)]

            def group_decode(gp, gc, x, kinds=kinds):
                newg = {}
                for j, kind in enumerate(kinds):
                    x, nc = self._block_decode(gp[f"blk{j:02d}"],
                                               gc[f"blk{j:02d}"], x, kind, pos)
                    newg[f"blk{j:02d}"] = nc
                return x, newg

            if st.scanned:
                def scan_body(x, gp_gc):
                    gp, gc = gp_gc
                    x, newg = group_decode(gp, gc, x)
                    return x, newg
                x, newg = jax.lax.scan(scan_body, x, (sp, sc))
                new_caches.append(newg)
            else:
                x, newg = group_decode(sp, sc, x)
                new_caches.append(newg)
        logits = self._head(params, x)
        return logits, new_caches

    # ------------------------------------------------------------------
    # Prefill: run the full forward and materialize the cache.
    # ------------------------------------------------------------------

    def prefill(self, params, batch, max_len: int = 0):
        """batch: tokens [B,S] (+ prefix/enc embeds).  Returns (last-token
        logits [B,V], cache primed with positions 0..S-1)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        max_len = max_len or s
        enc_out = None
        enc_len = 0
        if cfg.enc_dec:
            enc = batch["enc_embeds"].astype(_dtype(cfg))
            rope_e = self._rope(jnp.arange(enc.shape[1]))
            enc, _, _ = self._run_stages(params, enc, rope_e, encoder=True)
            enc_out = layers.rms_norm(enc, params["enc_norm"], cfg.norm_eps)
            enc_len = enc_out.shape[1]
            x = self._embed(params, tokens) + self._sinusoid(
                jnp.arange(s))[None]
        else:
            x = self._embed(params, tokens, batch.get("prefix_embeds"))
        rope = self._rope(jnp.arange(s))
        x, _, states = self._run_stages(params, x, rope, enc_out=enc_out,
                                        collect_states=True)
        logits = self._head(params, x[:, -1:, :])[:, 0]

        # Build the decode cache from collected block states.
        cache = self.init_cache(b, max_len, enc_len)
        layout = self.stage_layout()
        out_cache = list(cache)
        for idx, (st, st_states) in enumerate(zip(layout, states)):
            if st.encoder or st_states is None:
                continue
            sc = cache[idx]
            kinds = [self._block_kind(st.first_layer + j, False)
                     for j in range(st.period)]
            for j, kind in enumerate(kinds):
                blk = sc[f"blk{j:02d}"]
                bstate = st_states[f"blk{j:02d}"]
                if kind["mixer"] == "attn":
                    k, v = bstate["kv"]   # [B,S,H,D] / scanned [R,B,S,H,D]
                    cl = blk["k"].shape[-3]
                    kk, vv, spos = self._prime_cache_arrays(k, v, cl, s)
                    blk["k"], blk["v"], blk["slot_pos"] = kk, vv, spos
                else:
                    for key in ("mamba", "mlstm", "slstm"):
                        if key in bstate:
                            blk[key] = bstate[key]
                if kind["cross"]:
                    cross_p = params["stages"][idx][f"blk{j:02d}"]["cross"]
                    if st.scanned:
                        ke, ve = jax.vmap(
                            lambda ap: self._enc_kv(ap, enc_out))(cross_p)
                    else:
                        ke, ve = self._enc_kv(cross_p, enc_out)
                    blk["xk"], blk["xv"] = ke, ve
            out_cache[idx] = sc
        return logits, out_cache

    def _prime_cache_arrays(self, k, v, cache_len, s):
        """Place the last ``cache_len`` positions into the (ring) cache.
        k is already rope'd at its absolute position (applied in
        ``_attn_train``).  Works for stacked [R,B,S,H,D] and [B,S,H,D]."""

        def one(kr, v):
            if s <= cache_len:
                pad = cache_len - s
                kk = jnp.pad(kr, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                spos = jnp.where(jnp.arange(cache_len) < s,
                                 jnp.arange(cache_len), -1)
            else:
                # ring buffer: keep last cache_len positions
                positions = np.arange(s - cache_len, s)
                slots = positions % cache_len
                kk = jnp.zeros((kr.shape[0], cache_len) + kr.shape[2:],
                               kr.dtype)
                vv = jnp.zeros_like(kk)
                kk = kk.at[:, slots].set(kr[:, -cache_len:])
                vv = vv.at[:, slots].set(v[:, -cache_len:])
                spos = jnp.zeros((cache_len,), jnp.int32).at[slots].set(
                    jnp.asarray(positions, jnp.int32))
            return kk, vv, spos

        if k.ndim == 5:
            kk, vv, spos = jax.vmap(one)(k, v)
            return kk, vv, spos
        return one(k, v)
