"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential recurrence).  [arXiv:2405.04517]

TPU adaptation: the original sLSTM CUDA kernel relies on register-level
recurrence; here the sLSTM runs as a ``lax.scan`` over time with a small
[B, d] state (throughput-irrelevant at 125M scale), while the mLSTM — the
dominant block type — uses the chunkwise-parallel form (intra-chunk
attention-like einsums on the MXU + inter-chunk (C, n, m) carry), the same
schedule used for our Mamba port.

Stabilized exponential gating follows the paper: running log-max state m
keeps i/f gate products in range.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

PROJ_FACTOR_M = 2.0     # mLSTM up-projection factor
PROJ_FACTOR_S = 4.0 / 3  # sLSTM FFN factor


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in = int(PROJ_FACTOR_M * d)
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "up_x": layers.dense_init(ks[0], d, d_in, dtype),
        "up_z": layers.dense_init(ks[1], d, d_in, dtype),
        "w_q": layers.dense_init(ks[2], d_in, d_in, dtype),
        "w_k": layers.dense_init(ks[3], d_in, d_in, dtype),
        "w_v": layers.dense_init(ks[4], d_in, d_in, dtype),
        "w_if": layers.dense_init(ks[5], d_in, 2 * h, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.full((h,), 3.0)]),
        "skip_scale": jnp.ones((d_in,), dtype),
        "norm_scale": jnp.ones((d_in,), dtype),
        "down": layers.dense_init(ks[6], d_in, d, dtype),
    }


def _mlstm_chunk(q, k, v, logf, logi, C0, n0, m0):
    """Chunkwise-parallel mLSTM cell.

    q/k/v: [B,ck,H,Dh]; logf/logi: [B,ck,H] (log forget / log input gate);
    carries C0 [B,H,Dh,Dh], n0 [B,H,Dh], m0 [B,H].
    Returns (y [B,ck,H,Dh], C1, n1, m1).
    """
    b, ck, h, dh = q.shape
    F = jnp.cumsum(logf, axis=1)                        # [B,ck,H] log prod f
    # log weight of input s surviving to position t: F_t - F_s + logi_s
    lw = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]  # [B,t,s,H]
    causal = jnp.tril(jnp.ones((ck, ck), bool))
    lw = jnp.where(causal[None, :, :, None], lw, -jnp.inf)
    # carry weight of initial state at position t: F_t + m0
    lw0 = F + m0[:, None, :]                            # [B,t,H]
    m = jnp.maximum(lw.max(axis=2), lw0)                # [B,t,H] stabilizer
    m = jnp.maximum(m, -1e30)
    w = jnp.exp(lw - m[:, :, None, :])                  # [B,t,s,H]
    w0 = jnp.exp(lw0 - m)                               # [B,t,H]

    scale = 1.0 / math.sqrt(dh)
    att = jnp.einsum("bthd,bshd->btsh", q * scale, k) * w
    num = jnp.einsum("btsh,bshd->bthd", att, v) + \
        w0[..., None] * jnp.einsum("bthd,bhde->bthe", q * scale, C0)
    # denominator: qn = q . n_t where n_t = sum_s w[t,s] k_s + w0 * n0
    nsum = jnp.einsum("btsh,bshd->bthd", w, k) + w0[..., None] * n0[:, None]
    qn = jnp.einsum("bthd,bthd->bth", q * scale, nsum)
    den_t = jnp.maximum(jnp.abs(qn), jnp.exp(-m))       # xLSTM max(|qn|, e^-m)
    y = num / den_t[..., None]

    # chunk-final carries
    mf = jnp.maximum(F[:, -1] + m0, (F[:, -1:] - F + logi).max(axis=1))
    wk = jnp.exp(F[:, -1:, :] - F + logi - mf[:, None, :])   # [B,s,H]
    C1 = jnp.exp(F[:, -1] + m0 - mf)[..., None, None] * C0 + jnp.einsum(
        "bsh,bshd,bshe->bhde", wk, k, v)
    n1 = jnp.exp(F[:, -1] + m0 - mf)[..., None] * n0 + jnp.einsum(
        "bsh,bshd->bhd", wk, k)
    return y, C1, n1, mf


def mlstm_apply(params: dict, x: jax.Array, cfg: ModelConfig,
                chunk: int = 64, return_state: bool = False):
    b, s, d = x.shape
    h = cfg.num_heads
    xu = x @ params["up_x"]
    z = x @ params["up_z"]
    d_in = xu.shape[-1]
    dh = d_in // h
    q = (xu @ params["w_q"]).reshape(b, s, h, dh).astype(jnp.float32)
    k = (xu @ params["w_k"]).reshape(b, s, h, dh).astype(jnp.float32)
    v = (xu @ params["w_v"]).reshape(b, s, h, dh).astype(jnp.float32)
    gif = xu.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    logi, logf = gif[..., :h], jax.nn.log_sigmoid(gif[..., h:])

    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))

    def body(carry, inp):
        C, n, m = carry
        qb, kb, vb, lib, lfb = inp
        y, C, n, m = jax.checkpoint(_mlstm_chunk)(qb, kb, vb, lfb, lib, C, n, m)
        return (C, n, m), y

    toc = lambda t: t.reshape((b, n_chunks, chunk) + t.shape[2:]
                              ).transpose((1, 0, 2) + tuple(
                                  range(3, t.ndim + 1)))
    C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), 0.0, jnp.float32)
    state, ys = jax.lax.scan(body, (C0, n0, m0),
                             (toc(q), toc(k), toc(v), toc(logi), toc(logf)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, dh)[:, :s]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = layers.rms_norm(y, params["norm_scale"], 1e-6)
    y = y + params["skip_scale"][None, None] * xu
    y = y * jax.nn.silu(z)
    out = y @ params["down"]
    if not return_state:
        return out
    # Pads are exact state no-ops: logi padded -inf (zero input weight),
    # logf padded 0 (forget factor 1).
    C1, n1, m1 = state
    return out, {"C": C1, "n": n1, "m": m1}


def mlstm_cache_init(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.num_heads
    d_in = int(PROJ_FACTOR_M * cfg.d_model)
    dh = d_in // h
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


def mlstm_decode_step(params: dict, cache: dict, x: jax.Array,
                      cfg: ModelConfig) -> tuple[jax.Array, dict]:
    b, _, d = x.shape
    h = cfg.num_heads
    xu = x @ params["up_x"]
    z = x @ params["up_z"]
    d_in = xu.shape[-1]
    dh = d_in // h
    q = (xu @ params["w_q"]).reshape(b, h, dh).astype(jnp.float32)
    k = (xu @ params["w_k"]).reshape(b, h, dh).astype(jnp.float32)
    v = (xu @ params["w_v"]).reshape(b, h, dh).astype(jnp.float32)
    gif = xu[:, 0].astype(jnp.float32) @ params["w_if"] + params["b_if"]
    logi, logf = gif[..., :h], jax.nn.log_sigmoid(gif[..., h:])
    C, n, m0 = cache["C"], cache["n"], cache["m"]
    m = jnp.maximum(logf + m0, logi)
    fw = jnp.exp(logf + m0 - m)
    iw = jnp.exp(logi - m)
    C = fw[..., None, None] * C + iw[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v)
    n = fw[..., None] * n + iw[..., None] * k
    scale = 1.0 / math.sqrt(dh)
    num = jnp.einsum("bhd,bhde->bhe", q * scale, C)
    qn = jnp.einsum("bhd,bhd->bh", q * scale, n)
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m))
    y = (num / den[..., None]).reshape(b, 1, d_in).astype(x.dtype)
    y = layers.rms_norm(y, params["norm_scale"], 1e-6)
    y = y + params["skip_scale"][None, None] * xu
    y = y * jax.nn.silu(z)
    return y @ params["down"], {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    d_ff = int(PROJ_FACTOR_S * d)
    return {
        "w_gates": layers.dense_init(ks[0], d, 4 * d, jnp.float32),
        "r_gates": layers.dense_init(ks[1], d, 4 * d, jnp.float32),
        "b_gates": jnp.zeros((4 * d,)),
        "gn_scale": jnp.ones((d,), dtype),
        "ffn": layers.mlp_init(ks[2], d, d_ff, "swiglu", dtype),
    }


def _slstm_cell(params, x_t, state):
    """One step. x_t: [B,d] fp32; state: (c, n, h, m) each [B,d]."""
    c, n, h, m = state
    g = x_t @ params["w_gates"] + h @ params["r_gates"] + params["b_gates"]
    d = x_t.shape[-1]
    zt, it, ft, ot = g[:, :d], g[:, d:2*d], g[:, 2*d:3*d], g[:, 3*d:]
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(it - m_new)
    c = fw * c + iw * zt
    n = fw * n + iw
    h = ot * c / jnp.maximum(jnp.abs(n), 1.0)
    return (c, n, h, m_new)


def slstm_apply(params: dict, x: jax.Array, chunk: int = 128,
                return_state: bool = False):
    b, s, d = x.shape
    xf = x.astype(jnp.float32)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
    # validity mask: pad steps must be exact state no-ops
    valid = (jnp.arange(n_chunks * chunk) < s).astype(jnp.float32)

    def chunk_fn(state, xs_valid):
        xs, vs = xs_valid

        def step(st, xv):
            xt, vt = xv
            new = _slstm_cell(params, xt, st)
            new = tuple(jnp.where(vt > 0, a, b) for a, b in zip(new, st))
            return new, new[2]
        return jax.lax.scan(step, state, (xs, vs))

    def body(state, xs_valid):
        state, hs = jax.checkpoint(chunk_fn)(state, xs_valid)
        return state, hs

    z = jnp.zeros((b, d), jnp.float32)
    state0 = (z, z, z, z)
    xs = xf.reshape(b, n_chunks, chunk, d).transpose(1, 2, 0, 3)  # [nc,ck,B,d]
    vs = valid.reshape(n_chunks, chunk)[:, :, None, None] * jnp.ones(
        (1, 1, b, 1), jnp.float32)
    state, hs = jax.lax.scan(body, state0, (xs, vs))
    h = hs.transpose(2, 0, 1, 3).reshape(b, n_chunks * chunk, d)[:, :s]
    h = h.astype(x.dtype)
    h = layers.rms_norm(h, params["gn_scale"], 1e-6)
    out = h + layers.mlp_apply(params["ffn"], h, "swiglu")
    if not return_state:
        return out
    c, n, hh, m = state
    return out, {"c": c, "n": n, "h": hh, "m": m}


def slstm_cache_init(cfg: ModelConfig, batch: int) -> dict:
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_decode_step(params: dict, cache: dict, x: jax.Array
                      ) -> tuple[jax.Array, dict]:
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state = _slstm_cell(params, x[:, 0].astype(jnp.float32), state)
    c, n, h, m = state
    y = h[:, None].astype(x.dtype)
    y = layers.rms_norm(y, params["gn_scale"], 1e-6)
    y = y + layers.mlp_apply(params["ffn"], y, "swiglu")
    return y, {"c": c, "n": n, "h": h, "m": m}
