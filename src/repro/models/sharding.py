"""Parameter partitioning rules (t5x-style regex table).

Specs are *right-aligned*: a rule gives the PartitionSpec for a leaf's
trailing dims; leading dims (e.g. the stacked repeat axis of scanned
stages, or the expert axis position) are padded with ``None``.  The
``model`` axis is the GSPMD-auto tensor-parallel axis; ``EP`` is replaced
by the configured expert-parallel axis (a *manual* data axis) or dropped.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P

EP = "__EP__"

# (regex on the leaf's path, right-aligned partition entries).
# Order matters: expert (``*_e``) rules shadow the dense ones.
RULES: list[tuple[str, tuple]] = [
    (r"(w_gate_e|w_up_e)", (EP, None, "model")),
    (r"w_down_e", (EP, "model", None)),
    (r"(router|b_gates|gn_scale|norm|dec_pos|b_if|w_if)", ()),
    (r"(w_q|w_k|w_v|w_gate|w_up|up_x|up_z|in_proj|w_dt_up|w_gates|r_gates)",
     (None, "model")),
    (r"(w_o|w_down|\['down'\]|out_proj)", ("model", None)),
    (r"embed", ("model", None)),
    (r"lm_head", (None, "model")),
    (r"(A_log|w_bc|w_dt_down)", ("model", None)),
    (r"conv_w", (None, "model")),
    (r"(conv_b|dt_bias|\['D'\]|b_q|b_k|b_v|norm_scale|skip_scale|b_up|b_down)",
     ("model",)),
]


def spec_for_path(path: str, ndim: int, ep_axis: str = "",
                  tp_axis: str = "model",
                  moe_token_shard: bool = False) -> P:
    if moe_token_shard and re.search(r"w_(gate|up|down)_e", path):
        # token-sharded expert compute: weights replicated across TP
        out = [ep_axis if ep_axis else None, None, None]
        out = [None] * (ndim - 3) + out
        return P(*out[:ndim]) if ndim else P()
    for pat, entries in RULES:
        if re.search(pat, path):
            out = []
            for e in entries:
                if e == EP:
                    out.append(ep_axis if ep_axis else None)
                elif e == "model":
                    out.append(tp_axis if tp_axis else None)
                else:
                    out.append(e)
            out = [None] * (ndim - len(out)) + out
            return P(*out[:ndim]) if ndim else P()
    return P(*([None] * ndim)) if ndim else P()


def param_pspecs(params_shape, ep_axis: str = "", tp_axis: str = "model",
                 moe_token_shard: bool = False):
    """Pytree of PartitionSpec mirroring an eval_shape'd param tree."""
    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        return spec_for_path(p, len(leaf.shape), ep_axis, tp_axis,
                             moe_token_shard)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def filter_uneven(pspecs, shapes_tree, mesh_dims: dict):
    """Drop spec entries whose axis product does not divide the dim.

    ``device_put`` (and manual shard_map axes) require even sharding; GSPMD
    would pad, but padding a 85-row tensor across 2 shards silently wastes
    memory anyway — replicating such leaves is the right default.
    """
    def one(spec, leaf):
        if not isinstance(spec, P):
            return spec
        out = []
        for d, e in enumerate(spec):
            if e is None:
                out.append(None)
                continue
            names = (e,) if isinstance(e, str) else tuple(e)
            factor = 1
            for n in names:
                factor *= mesh_dims.get(n, 1)
            if d < len(leaf.shape) and leaf.shape[d] % factor == 0:
                out.append(e)
            else:
                out.append(None)
        return P(*out)

    return jax.tree.map(one, pspecs, shapes_tree,
                        is_leaf=lambda x: isinstance(x, P))


def manual_only(spec: P, manual_axes: frozenset[str]) -> P:
    """Project a full PartitionSpec onto the manual axes (shard_map
    in_specs must not mention auto axes)."""
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, str):
            out.append(e if e in manual_axes else None)
        else:
            kept = tuple(x for x in e if x in manual_axes)
            out.append(kept if kept else None)
    return P(*out)


def auto_only(spec: P, manual_axes: frozenset[str]) -> P:
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, str):
            out.append(e if e not in manual_axes else None)
        else:
            kept = tuple(x for x in e if x not in manual_axes)
            out.append(kept if kept else None)
    return P(*out)
