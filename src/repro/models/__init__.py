"""Model zoo: all assigned architectures as one unified LM class."""
from repro.models.transformer import LM
from repro.models.registry import (ARCHS, ArchBundle, get_arch, list_archs,
                                   reduced_arch, cells)
