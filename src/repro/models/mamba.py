"""Mamba selective-SSM block (Jamba's sequence mixer).

TPU adaptation: the CUDA selective-scan kernel of the original paper is a
fused recurrent kernel; on TPU we use a *chunked associative scan* —
``lax.associative_scan`` of the affine recurrence within fixed-size chunks
(SIMD/MXU friendly, bounded VMEM working set) and a sequential ``lax.scan``
carrying the [B, d_inner, d_state] hidden across chunks.  Decode is the O(1)
single-step recurrence against a cached (h, conv window) state.

Recurrence (discretized selective SSM):

    h_t = exp(dt_t * A) ⊙ h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t
    y_t = (h_t · C_t) + D ⊙ x_t
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    mc = cfg.mamba
    d = cfg.d_model
    d_inner = mc.expand * d
    dt_rank = mc.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 8)
    # S4D-real initialization for A.
    a = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    p = {
        "in_proj": layers.dense_init(ks[0], d, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, d_inner), jnp.float32)
                   / math.sqrt(mc.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_bc": layers.dense_init(ks[2], d_inner, 2 * mc.d_state, dtype),
        "w_dt_down": layers.dense_init(ks[3], d_inner, dt_rank, dtype),
        "w_dt_up": layers.dense_init(ks[4], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(a),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": layers.dense_init(ks[5], d_inner, d, dtype),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 carry: jax.Array | None = None):
    """Depthwise causal conv1d. x: [B,S,C]; w: [K,C].  Returns (y, new_carry)
    where carry is the last K-1 inputs (decode state)."""
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    new_carry = xp[:, -(k - 1):, :] if k > 1 else carry
    return y + b[None, None, :], new_carry


def _ssm_params(params, xc):
    """Common projections. xc: [B,S,d_inner] (post-conv, post-silu)."""
    d_state = params["A_log"].shape[1]
    bc = xc @ params["w_bc"]
    B, C = bc[..., :d_state], bc[..., d_state:]
    dt = jax.nn.softplus(
        (xc @ params["w_dt_down"]) @ params["w_dt_up"]
        + params["dt_bias"]).astype(jnp.float32)              # [B,S,d_inner]
    A = -jnp.exp(params["A_log"])                              # [d_inner,N]
    return B.astype(jnp.float32), C.astype(jnp.float32), dt, A


def mamba_apply(params: dict, x: jax.Array, chunk: int = 64,
                return_state: bool = False):
    """Train/prefill path. x: [B,S,d_model] -> [B,S,d_model].

    With ``return_state`` also returns the decode cache ({h, conv}) after
    consuming the sequence (prefill priming)."""
    b, s, _ = x.shape
    xz = x @ params["in_proj"]
    d_inner = xz.shape[-1] // 2
    xpart, z = xz[..., :d_inner], xz[..., d_inner:]
    xc, _ = _causal_conv(xpart, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    Bm, Cm, dt, A = _ssm_params(params, xc)

    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p = xc

    def chunk_fn(h0, inp):
        xcb, Bb, Cb, dtb = inp          # [B,ck,*]
        # decay exponents and inputs for the affine scan
        dA = dtb[..., None] * A[None, None]                   # [B,ck,di,N]
        a = jnp.exp(dA)
        u = (dtb * xcb.astype(jnp.float32))[..., None] * Bb[:, :, None, :]

        def op(l, r):
            (al, bl), (ar, br) = l, r
            return al * ar, bl * ar + br

        a_c, u_c = jax.lax.associative_scan(op, (a, u), axis=1)
        h = a_c * h0[:, None] + u_c                            # [B,ck,di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h, Cb)
        return h[:, -1], y

    def scan_body(h, inp):
        h, y = jax.checkpoint(chunk_fn)(h, inp)
        return h, y

    h0 = jnp.zeros((b, d_inner, A.shape[1]), jnp.float32)
    to_chunks = lambda t: t.reshape(b, n_chunks, chunk, t.shape[-1]
                                    ).transpose(1, 0, 2, 3)
    h_last, ys = jax.lax.scan(scan_body, h0, (to_chunks(xc_p), to_chunks(Bm),
                                              to_chunks(Cm), to_chunks(dt)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, n_chunks * chunk, d_inner)[:, :s]
    y = y + params["D"][None, None] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    # Pad steps are exact no-ops on the state: dt is padded with zeros
    # *after* softplus, so decay = exp(0) = 1 and input term = 0.
    k = params["conv_w"].shape[0]
    xpad = jnp.concatenate(
        [jnp.zeros((b, k - 1, d_inner), x.dtype), xpart], axis=1)
    conv_carry = xpad[:, xpad.shape[1] - (k - 1):, :]
    return out, {"h": h_last, "conv": conv_carry}


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_inner, mc.d_state), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, d_inner), dtype),
    }


def mamba_decode_step(params: dict, cache: dict, x: jax.Array
                      ) -> tuple[jax.Array, dict]:
    """O(1) decode. x: [B,1,d_model] -> (y [B,1,d_model], new cache)."""
    xz = x @ params["in_proj"]
    d_inner = xz.shape[-1] // 2
    xpart, z = xz[..., :d_inner], xz[..., d_inner:]
    xc, conv_carry = _causal_conv(xpart, params["conv_w"], params["conv_b"],
                                  cache["conv"])
    xc = jax.nn.silu(xc)
    Bm, Cm, dt, A = _ssm_params(params, xc)
    a = jnp.exp(dt[:, 0, :, None] * A[None])                   # [B,di,N]
    u = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = a * cache["h"] + u
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]
    y = y + params["D"][None, None] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], {"h": h, "conv": conv_carry}
