"""Architecture registry: ``--arch <id>`` -> config + model + input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given (architecture × input-shape) cell — weak-type
correct, shardable, no device allocation — exactly what the multi-pod
dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ParallelConfig, RunConfig,
                                ShapeConfig, SHAPES, reduced)
from repro.models.transformer import LM

ARCHS = {
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-67b": "deepseek_67b",
    "gemma3-12b": "gemma3_12b",
    "stablelm-1.6b": "stablelm_1_6b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "arctic-480b": "arctic_480b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-base": "whisper_base",
    "xlstm-125m": "xlstm_125m",
}


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    name: str
    cfg: ModelConfig
    parallel: ParallelConfig
    microbatch: dict
    skip_shapes: dict
    optimizer_state_dtype: str = "float32"

    def model(self, parallel: ParallelConfig | None = None) -> LM:
        return LM(self.cfg, parallel or self.parallel)

    def run_config(self, shape_name: str,
                   parallel: ParallelConfig | None = None) -> RunConfig:
        return RunConfig(
            model=self.cfg,
            shape=SHAPES[shape_name],
            parallel=parallel or self.parallel,
            microbatch=self.microbatch.get(shape_name, 0),
            optimizer_state_dtype=self.optimizer_state_dtype,
        )


def get_arch(name: str) -> ArchBundle:
    if name not in ARCHS:
        raise ValueError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return ArchBundle(
        name=name,
        cfg=mod.CONFIG,
        parallel=mod.PARALLEL,
        microbatch=mod.MICROBATCH,
        skip_shapes=mod.SKIP_SHAPES,
        optimizer_state_dtype=getattr(mod, "OPTIMIZER_STATE_DTYPE",
                                      "float32"),
    )


def list_archs() -> list[str]:
    return sorted(ARCHS)


def reduced_arch(name: str, **kw) -> ArchBundle:
    """Same-family reduced config for CPU smoke tests."""
    b = get_arch(name)
    small = reduced(b.cfg, **kw)
    par = dataclasses.replace(b.parallel, ep_axis="", attn_chunk=64)
    return dataclasses.replace(b, cfg=small, parallel=par)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins).
# ---------------------------------------------------------------------------

def cells(arch: str) -> list[str]:
    """Applicable shape names for an arch (assigned minus skips)."""
    b = get_arch(arch)
    out = []
    for s in SHAPES:
        if s in b.skip_shapes:
            continue
        out.append(s)
    return out


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                      batch_override: int = 0) -> dict:
    """Global-shape ShapeDtypeStructs for one train step's batch."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.enc_dec:
        specs["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    if cfg.frontend == "vision":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_prefix_len, cfg.d_model), dt)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    return train_input_specs(cfg, shape) | {}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       model: LM) -> dict:
    """Token + KV-cache ShapeDtypeStructs for one decode step."""
    b, s = shape.global_batch, shape.seq_len
    enc_len = s if cfg.enc_dec else 0
    cache = jax.eval_shape(lambda: model.init_cache(b, s, enc_len))
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
