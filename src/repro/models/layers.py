"""Neural-net building blocks shared by all assigned architectures.

Pure-functional JAX: params are plain pytrees of arrays; every ``apply``
function is jit/vjp-safe.  Tensor-parallel sharding is expressed with
``with_sharding_constraint`` on the GSPMD-auto ``model`` axis (safe no-op
when no mesh with that axis is active, so single-device smoke tests run the
identical code).

The attention core is a chunked online-softmax (flash-attention schedule in
pure ``lax.scan`` form) so 32k-524k sequence dry-runs lower without
materializing S×S score matrices; the Pallas kernel in
``repro/kernels/flash_attention`` implements the same schedule with explicit
VMEM tiling for the TPU target and is validated against
:func:`attention_ref`.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Sharding helper.
# ---------------------------------------------------------------------------

# None on old JAX (< 0.5), where axis types don't exist yet.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _active_mesh_axis_names():
    """Non-Manual axis names of the ambient mesh, or None when no mesh.

    New JAX: the abstract mesh installed by ``jax.set_mesh`` / shard_map.
    Old JAX (0.4.x): the ``with mesh:`` pjit resource env.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        if mesh is None or mesh.empty:
            return None
        if _AXIS_TYPE is not None:
            try:
                return {n for n, t in zip(mesh.axis_names, mesh.axis_types)
                        if t != _AXIS_TYPE.Manual}
            except Exception:
                pass
        return set(mesh.axis_names)
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or mesh.empty:
        return None
    try:
        from jax._src import core as _core
        if _core.get_axis_env().axis_sizes:
            # Inside a shard_map body: old XLA cannot mix sharding
            # annotations with manual subgroups, so skip the hint.
            return None
    except Exception:
        pass
    return set(mesh.axis_names)


def unroll_scans_here() -> bool:
    """True when tracing inside a shard_map body on old JAX (< 0.5).

    XLA of that era cannot partition ``lax.scan`` loops whose bodies sit in
    a manual subgroup (fatal ``IsManualSubgroup`` check); callers unroll the
    loop instead — identical math, longer compile.
    """
    if hasattr(jax, "shard_map"):
        return False
    try:
        from jax._src import core as _core
        return bool(_core.get_axis_env().axis_sizes)
    except Exception:
        return False


def pshard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that degrades to identity off-mesh and
    ignores axes that are manual in the current (shard_map) context."""
    try:
        names = _active_mesh_axis_names()
    except Exception:
        return x
    if names is None:
        return x
    clean = []
    for s in spec:
        if s is None:
            clean.append(None)
        elif isinstance(s, str):
            clean.append(s if s in names else None)
        else:  # tuple of names
            kept = tuple(n for n in s if n in names)
            clean.append(kept if kept else None)
    if all(c is None for c in clean):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*clean))


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions [.. S]."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2 / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freq  # [..., S, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, D]; cos/sin: [S, D/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
                           ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (flash schedule in lax.scan form).
# ---------------------------------------------------------------------------

def _gqa_expand(q: jax.Array, num_kv: int) -> jax.Array:
    """[B,S,Hq,D] -> [B,S,Hkv,G,D]."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, num_kv, hq // num_kv, d)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset=0, kv_len: Optional[jax.Array] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Full-materialization reference attention (tests / tiny shapes).

    q: [B,Sq,Hq,D], k/v: [B,Skv,Hkv,D]; GQA via head grouping.
    ``window > 0`` keeps keys with q_pos - k_pos in [0, window).
    ``kv_len`` ([B] int) masks cache positions >= kv_len (decode).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _gqa_expand(q * scale, hkv)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    mask = jnp.broadcast_to(mask[None], (b, sq, skv))
    if kv_len is not None:
        mask &= k_pos[None, None, :] < kv_len[:, None, None]
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m) * mask[:, None, None]   # 0 for fully-masked rows
    w = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset=0, kv_len: Optional[jax.Array] = None,
              chunk: int = 1024, scale: Optional[float] = None) -> jax.Array:
    """Memory-efficient attention: online softmax over KV chunks.

    Never materializes more than [B, Sq, H, chunk] of scores; exact same
    result as :func:`attention_ref` (tested).  This is the form the Pallas
    flash kernel implements with VMEM tiles on the TPU target.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if skv <= chunk:
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, kv_len=kv_len, scale=scale)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _gqa_expand(q.astype(jnp.float32) * scale, hkv)   # [B,Sq,K,G,D]
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, n_chunks, chunk, hkv, d)
    vc = v.reshape(b, n_chunks, chunk, hkv, d)
    starts = jnp.arange(n_chunks) * chunk

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, start = inp                                # [B,C,K,D]
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.float32))
        k_pos = start + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < skv)[None, :]
        mask = jnp.broadcast_to(mask[None], (b, sq, chunk))
        if kv_len is not None:
            mask = mask & (k_pos[None, None, :] < kv_len[:, None, None])
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None]) * mask[:, None, None]
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    g = hq // hkv
    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # [B,K,G,Sq,D]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def decode_attend_seqsharded(q, k_local, v_local, *, axis: str,
                             shard_idx, kv_len, scale=None) -> jax.Array:
    """Single-token attention against a sequence-sharded KV cache.

    Used for ``long_500k`` (batch=1): the cache's sequence dim is sharded
    over the manual ``data`` axis; each shard computes partial (max, sum,
    acc) over its local chunk and the exact softmax is reconstructed with
    two psums + one pmax (flash-decode).  q: [B,1,Hq,D];
    k/v_local: [B,S_local,Hkv,D]; kv_len: [B] global valid length.
    """
    b, sq, hq, d = q.shape
    s_local, hkv = k_local.shape[1], k_local.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _gqa_expand(q.astype(jnp.float32) * scale, hkv)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_local.astype(jnp.float32))
    k_pos = shard_idx * s_local + jnp.arange(s_local)
    mask = k_pos[None, :] < kv_len[:, None]                 # [B,S_local]
    logits = jnp.where(mask[:, None, None, None], logits, NEG_INF)
    m_loc = logits.max(axis=-1)
    m_glob = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(logits - m_glob[..., None]) * mask[:, None, None, None]
    l = jax.lax.psum(p.sum(axis=-1), axis)
    acc = jax.lax.psum(
        jnp.einsum("bkgst,btkd->bkgsd", p, v_local.astype(jnp.float32)), axis)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------

def mlp_apply(params: dict, x: jax.Array, act: str = "swiglu") -> jax.Array:
    """SwiGLU (w_gate/w_up/w_down) or GELU (w_up/w_down) MLP with TP
    constraints on the hidden dim."""
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"] +
                        params.get("b_up", jnp.zeros((), x.dtype)))
    h = pshard(h, *([None] * (h.ndim - 1) + ["model"]))
    out = h @ params["w_down"]
    if "b_down" in params:
        out = out + params["b_down"]
    return out


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype,
             bias: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    p = {}
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[0], d_model, d_ff, dtype)
    p["w_up"] = dense_init(ks[1], d_model, d_ff, dtype)
    p["w_down"] = dense_init(ks[2], d_ff, d_model, dtype)
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# Attention block params + apply.
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, dtype, qkv_bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "w_k": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "w_v": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "w_o": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["b_q"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["b_k"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["b_v"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def qkv_proj(params: dict, x: jax.Array, num_heads: int, num_kv_heads: int,
             head_dim: int):
    b, s, _ = x.shape
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if "b_q" in params:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = pshard(q.reshape(b, s, num_heads, head_dim), None, None, "model", None)
    k = pshard(k.reshape(b, s, num_kv_heads, head_dim), None, None, "model", None)
    v = pshard(v.reshape(b, s, num_kv_heads, head_dim), None, None, "model", None)
    return q, k, v


def out_proj(params: dict, o: jax.Array) -> jax.Array:
    b, s, h, d = o.shape
    return o.reshape(b, s, h * d) @ params["w_o"]
