"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, optional EP.

Covers the three assigned MoE flavours:

* deepseek-moe-16b — fine-grained: 64 routed experts top-6 **plus** 2
  always-on shared experts;
* arctic-480b      — 128 routed top-2 **plus** a dense FFN residual running
  in parallel;
* jamba-v0.1-52b   — 16 routed top-2 on every other layer.

Dispatch is sort-free capacity-style but built with a cumsum-free
*sort-position* trick to avoid T×E×C one-hot tensors: assignments are
argsorted by expert id and positions-within-group are recovered with a
cummax, so peak extra memory is O(T·k) integers.  Expert compute uses
stacked-weight einsums ([E, d, f]) so FLOPs scale with capacity·E =
tokens·top_k·capacity_factor, not with E.

Expert parallelism: when ``ep_axis`` names a *manual* shard_map axis, the
expert dim of the dispatch buffer is exchanged with ``lax.all_to_all`` so
each shard computes only its local experts (weights enter pre-sharded on
dim 0).  Expert gradients are then owned per-shard and excluded from the
MG-WFBP data-parallel reduction (see train/step.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "router": layers.dense_init(ks[0], d, m.num_experts, jnp.float32),
        "w_gate_e": _experts_init(ks[1], m.num_experts, d, m.d_expert, dtype),
        "w_up_e": _experts_init(ks[2], m.num_experts, d, m.d_expert, dtype),
        "w_down_e": _experts_init(ks[3], m.num_experts, m.d_expert, d, dtype),
    }
    if m.num_shared_experts:
        ds = m.shared_d_expert * m.num_shared_experts
        p["shared"] = layers.mlp_init(ks[4], d, ds, "swiglu", dtype)
    if cfg.dense_residual and cfg.d_ff > 0:
        p["dense_residual"] = layers.mlp_init(ks[5], d, cfg.d_ff, "swiglu",
                                              dtype)
    return p


def _experts_init(key, e: int, d_in: int, d_out: int, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def _positions_in_expert(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """Per-assignment arrival position within its expert, O(Tk log Tk).

    argsort by expert id; within the sorted order, positions are
    ``arange - group_start`` where group_start is recovered by a cummax
    over boundary markers; scatter back to unsorted order.
    """
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    idx = jnp.arange(n)
    is_start = jnp.concatenate([jnp.array([True]),
                                sorted_e[1:] != sorted_e[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_sorted = idx - group_start
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def _topk_compat(probs: jax.Array, k: int):
    """``lax.top_k`` with an iterative-argmax fallback.

    The variadic sort behind top_k crashes the old (JAX 0.4.x) SPMD
    partitioner inside a partial-auto shard_map; k is tiny (1-8) so k
    argmax passes are an adequate substitute there.
    """
    if not layers.unroll_scans_here():
        return jax.lax.top_k(probs, k)
    p = probs
    gates, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        gates.append(jnp.take_along_axis(p, i[:, None], axis=-1)[:, 0])
        idxs.append(i)
        p = jnp.where(jax.nn.one_hot(i, p.shape[-1], dtype=bool),
                      -jnp.inf, p)
    return jnp.stack(gates, axis=-1), jnp.stack(idxs, axis=-1)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              ep_axis: str = "", parallel=None
              ) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    Perf knobs (ParallelConfig, see §Perf):
      * ``moe_token_shard``  — shard expert compute over the capacity dim
        (expert weights replicated across TP): the down-projection then
        partitions over rows with NO partial-sum all-reduce of the
        7.5x-capacity buffer;
      * ``moe_combine_dtype`` — combine/scatter arithmetic dtype (fp32
        default; bf16 halves the backward all-to-all bytes);
      * ``moe_capacity_factor`` — override the config's 1.25."""
    m = cfg.moe
    token_shard = bool(parallel and parallel.moe_token_shard)
    cdt = (jnp.dtype(parallel.moe_combine_dtype)
           if parallel and parallel.moe_combine_dtype else jnp.float32)
    cap_factor = (parallel.moe_capacity_factor
                  if parallel and parallel.moe_capacity_factor
                  else m.capacity_factor)
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    # --- routing (fp32 for stability) ---
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)                   # [T, E]
    gate, eidx = _topk_compat(probs, m.top_k)                 # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((m.num_experts,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (t * m.top_k))
    aux = m.num_experts * jnp.sum(me * ce)

    # --- capacity dispatch ---
    from repro.core.comm import axis_size
    # Old JAX inside shard_map: the EP all_to_all trips the old SPMD
    # partitioner (like lax.scan — see layers.unroll_scans_here), so fall
    # back to computing every expert locally; the step function mirrors
    # this by treating expert grads as replicated.
    ep_ok = ep_axis and not layers.unroll_scans_here()
    ep = axis_size(ep_axis) if ep_ok else 1
    cap = int(math.ceil(t * m.top_k / m.num_experts * cap_factor))
    cap = max(8, -(-cap // 8) * 8)
    if ep > 1:
        cap = -(-cap // ep) * ep  # divisible for all_to_all tiling
    flat_e = eidx.reshape(-1)                                  # [T*k]
    pos = _positions_in_expert(flat_e, m.num_experts)
    keep = pos < cap
    dst = flat_e * cap + jnp.minimum(pos, cap - 1)             # [T*k]
    src_token = jnp.repeat(jnp.arange(t), m.top_k)
    disp = jnp.zeros((m.num_experts * cap, d), x.dtype)
    disp = disp.at[dst].add(
        jnp.where(keep[:, None], xf[src_token], 0).astype(x.dtype))
    disp = disp.reshape(m.num_experts, cap, d)

    # --- expert parallelism: exchange expert dim over the manual axis ---
    if ep > 1:
        disp = jax.lax.all_to_all(disp, ep_axis, split_axis=0, concat_axis=1,
                                  tiled=True)                  # [E/ep, cap*ep, d]
    wg, wu, wd = params["w_gate_e"], params["w_up_e"], params["w_down_e"]
    if token_shard:
        disp = layers.pshard(disp, None, "model", None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, wg)) * jnp.einsum(
        "ecd,edf->ecf", disp, wu)
    if token_shard:
        h = layers.pshard(h, None, "model", None)
    else:
        h = layers.pshard(h, None, None, "model")
    eout = jnp.einsum("ecf,efd->ecd", h, wd)
    if token_shard:
        eout = layers.pshard(eout, None, "model", None)
    if ep > 1:
        eout = jax.lax.all_to_all(eout, ep_axis, split_axis=1, concat_axis=0,
                                  tiled=True)                  # [E, cap, d]
    eout = eout.reshape(m.num_experts * cap, d)

    # --- combine ---
    gathered = eout[dst]                                        # [T*k, d]
    w = jnp.where(keep, gate.reshape(-1), 0.0).astype(cdt)
    out = jnp.zeros((t, d), cdt).at[src_token].add(
        gathered.astype(cdt) * w[:, None])
    out = out.astype(x.dtype)

    # --- always-on paths ---
    if "shared" in params:
        out = out + layers.mlp_apply(params["shared"], xf, "swiglu")
    if "dense_residual" in params:
        out = out + layers.mlp_apply(params["dense_residual"], xf, "swiglu")
    return out.reshape(b, s, d), aux
