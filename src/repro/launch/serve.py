"""Serving launcher: batched generation with a KV cache.

CPU-runnable example::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 4 --max-new 16
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")

    import time
    import jax
    import jax.numpy as jnp
    from repro.models import registry
    from repro.serve.engine import ServeEngine

    bundle = registry.reduced_arch(args.arch) if args.reduced \
        else registry.get_arch(args.arch)
    model = bundle.model()
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.max_new + 8,
                         temperature=args.temperature)

    key = jax.random.PRNGKey(7)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (args.prompt_len,), 0,
                                  bundle.cfg.vocab_size)
               for i in range(args.requests)]
    extra = {}
    if bundle.cfg.enc_dec:
        extra["enc_embeds"] = jnp.zeros(
            (args.requests, 32, bundle.cfg.d_model), jnp.bfloat16)
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=args.max_new,
                           extra_batch=extra)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"arch={bundle.cfg.name}: generated {total} tokens for "
          f"{args.requests} requests in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. prefill+compile)")
    for i, o in enumerate(outs[:2]):
        print(f"  req{i}: {o[:12]}...")


if __name__ == "__main__":
    main()
