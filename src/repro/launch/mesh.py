"""Production mesh construction.

Never touches jax device state at import time — everything is a function.
Mesh shapes: single-pod (16, 16) = 256 chips ("data", "model"); multi-pod
(2, 16, 16) = 512 chips ("pod", "data", "model").  ``pod`` is the DCN-level
data-parallel axis (high startup cost — where gradient merging pays most).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(devices: int, model_parallel: int = 0):
    """Best-effort mesh for an arbitrary device count (tests / CPU runs)."""
    if model_parallel <= 0:
        model_parallel = 1
        for cand in (16, 8, 4, 2):
            if devices % cand == 0 and devices // cand >= 1:
                model_parallel = cand
                break
    data = devices // model_parallel
    return jax.make_mesh(
        (data, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
