"""Production mesh construction + JAX version compatibility shims.

Never touches jax device state at import time — everything is a function.
Mesh shapes: single-pod (16, 16) = 256 chips ("data", "model"); multi-pod
(2, 16, 16) = 512 chips ("pod", "data", "model").  ``pod`` is the DCN-level
data-parallel axis (high startup cost — where gradient merging pays most).

Compatibility: new JAX (>= 0.5) exposes ``jax.sharding.AxisType`` and
``jax.set_mesh``; old JAX (0.4.x) has neither — ``jax.make_mesh`` takes no
``axis_types`` and the ambient mesh is set with the ``with mesh:`` resource
env.  :func:`make_mesh` and :func:`use_mesh` paper over the difference so
every call site (and the tests) runs on both.
"""

from __future__ import annotations

import contextlib

import jax

# None on old JAX (< 0.5); the AxisType enum on new JAX.
AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` on any JAX: request Auto axis types when supported.

    New JAX wants explicit ``axis_types`` for GSPMD-auto partitioning; old
    JAX predates axis types entirely (everything behaves as Auto).
    """
    if AXIS_TYPE is not None:
        try:
            return jax.make_mesh(
                shape, axes, devices=devices,
                axis_types=(AXIS_TYPE.Auto,) * len(axes))
        except TypeError:
            pass  # jax.make_mesh without the axis_types kwarg
    return jax.make_mesh(shape, axes, devices=devices)


@contextlib.contextmanager
def use_mesh(mesh):
    """Ambient-mesh context: ``jax.set_mesh`` on new JAX, ``with mesh:``
    (the pjit resource env) on old JAX.  Either way bare ``PartitionSpec``
    sharding constraints inside resolve against ``mesh``."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        with set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 0):
    """Best-effort mesh for an arbitrary device count (tests / CPU runs)."""
    if model_parallel <= 0:
        model_parallel = 1
        for cand in (16, 8, 4, 2):
            if devices % cand == 0 and devices // cand >= 1:
                model_parallel = cand
                break
    data = devices // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))
