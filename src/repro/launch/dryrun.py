import os
# 512 placeholder devices for the multi-pod mesh; single-pod cells may set
# DRYRUN_DEVICES=256 to halve compiler host memory (35 GB container limit).
_N_DEV = os.environ.get("DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           f" --xla_force_host_platform_device_count={_N_DEV}"
                           ).strip()

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
compiles, and fits — no real hardware, 512 placeholder CPU devices.

For each cell this script:
  1. builds the production mesh (single-pod (16,16) or multi-pod
     (2,16,16)),
  2. builds the real step function — ``train_step`` for train shapes,
     ``serve`` prefill/decode for inference shapes — with the arch's
     production parallelism config (ZeRO, EP, MG-WFBP plan),
  3. ``jit(...).lower(**input_specs).compile()`` against ShapeDtypeStruct
     stand-ins (no allocation),
  4. records ``memory_analysis()`` (fits-on-chip proof),
     ``cost_analysis()`` (XLA's once-per-scan-body costs) and the
     trip-count-corrected HLO costs + collective bytes (utils/hlo.py),
     plus the MG-WFBP plan actually baked into the step,
  5. writes one JSON artifact per cell to ``artifacts/dryrun/``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import registry, sharding as shd
from repro.models.transformer import LM
from repro.serve.engine import build_serve_step
from repro.train import step as step_mod
from repro.utils import flops as uflops, hlo as uhlo

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _sds(tree, spec_tree, mesh):
    """ShapeDtypeStructs with NamedShardings attached."""
    def one(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(one, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape_name: str, mesh_name: str,
             strategy: str | None = None, verbose: bool = True,
             par_overrides: dict | None = None,
             run_overrides: dict | None = None, tag: str = "") -> dict:
    """Lower + compile one cell; returns the artifact dict.

    ``par_overrides`` / ``run_overrides``: perf-loop knobs (remat policy,
    wire dtype, microbatch, ...) applied on top of the arch defaults."""
    bundle = registry.get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    dims = dict(zip(mesh.axis_names, mesh.devices.shape))
    par = bundle.parallel
    dp = (("pod",) if mesh_name == "multi" else ()) + ("data",) + \
        (() if par.tp_enabled else ("model",))
    # the global batch must divide the DP extent; if folding the idle
    # model axis into DP over-shards (e.g. batch 256 on the 512-chip
    # multi-pod mesh), leave the model axis out (replicated compute).
    dp_total = 1
    for a in dp:
        dp_total *= dims.get(a, 1)
    if shape.kind == "train" and shape.global_batch % dp_total and \
            "model" in dp:
        dp = tuple(a for a in dp if a != "model")
    par = dataclasses.replace(par, dp_axes=dp, **(par_overrides or {}))
    model = LM(bundle.cfg, par)
    run = bundle.run_config(shape_name, par)
    if run_overrides:
        run = dataclasses.replace(run, **run_overrides)
    kind = shape.kind
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": kind, "devices": int(mesh.devices.size),
           "strategy": strategy or par.comm_strategy, "ok": False,
           "tag": tag, "par_overrides": par_overrides or {},
           "run_overrides": run_overrides or {}}
    t0 = time.time()
    try:
        with use_mesh(mesh):
            if kind == "train":
                step_fn, init_fn, art = step_mod.build_train_step(
                    model, run, mesh, strategy=strategy)
                state_shape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
                state_in = _sds(state_shape, art.state_pspecs, mesh)
                batch_shape = registry.train_input_specs(bundle.cfg, shape)
                batch_in = jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(
                        l.shape, l.dtype,
                        sharding=NamedSharding(mesh, art.batch_pspec)),
                    batch_shape)
                rec["plan"] = {
                    "strategy": art.plan.strategy,
                    "num_buckets": art.plan.num_buckets,
                    "num_tensors": art.plan.num_tensors,
                    "bucket_bytes": art.plan.bucket_bytes(art.specs),
                }
                lowered = jax.jit(step_fn).lower(state_in, batch_in)
            else:
                decode_fn, prefill_fn, sh = build_serve_step(model, shape,
                                                             mesh)
                params_shape = jax.eval_shape(
                    lambda: model.init(jax.random.PRNGKey(0)))
                params_in = jax.tree.map(
                    lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                      sharding=s),
                    params_shape, sh["params"])
                if kind == "prefill":
                    batch_shape = registry.train_input_specs(bundle.cfg,
                                                             shape)
                    batch_shape.pop("labels")
                    batch_in = jax.tree.map(
                        lambda l: jax.ShapeDtypeStruct(
                            l.shape, l.dtype, sharding=sh["tokens"]
                            if l.shape[0] == shape.global_batch and
                            len(l.shape) == 2 else NamedSharding(mesh, P())),
                        batch_shape)
                    lowered = jax.jit(prefill_fn).lower(params_in, batch_in)
                else:  # decode
                    enc_len = shape.seq_len if bundle.cfg.enc_dec else 0
                    cache_shape = jax.eval_shape(
                        lambda: model.init_cache(shape.global_batch,
                                                 shape.seq_len, enc_len))
                    cache_in = jax.tree.map(
                        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                          sharding=s),
                        cache_shape, sh["cache"])
                    tok_in = jax.ShapeDtypeStruct(
                        (shape.global_batch, 1), jnp.int32,
                        sharding=sh["tokens"])
                    pos_in = jax.ShapeDtypeStruct((), jnp.int32)
                    lowered = jax.jit(decode_fn).lower(params_in, cache_in,
                                                       tok_in, pos_in)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            rec["memory"] = _mem_dict(compiled)
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, list):   # old JAX: one dict per partition
                ca = ca[0] if ca else {}
            rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                               "bytes": float(ca.get("bytes accessed", 0.0))}
            txt = compiled.as_text()
            h = uhlo.analyze(txt)
            rec["hlo"] = h.as_dict()
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            rec["model_flops"] = uflops.model_flops(bundle.cfg, params_shape,
                                                    shape, kind)
            rec["ok"] = True
            if verbose:
                mem = rec["memory"].get("total_hbm_bytes", 0)
                print(f"  [OK] {arch} × {shape_name} × {mesh_name}: "
                      f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
                      f"hlo_flops={rec['hlo']['flops']:.3e} "
                      f"coll_bytes={rec['hlo']['collective_bytes']:.3e} "
                      f"mem={mem/1e9:.2f}GB(prog)", flush=True)
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  [FAIL] {arch} × {shape_name} × {mesh_name}: "
                  f"{rec['error'][:200]}", flush=True)
    return rec


def save_artifact(rec: dict, out_dir: str = ARTIFACT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{rec['strategy']}" if rec.get("strategy") not in (
        None, "mgwfbp") else ""
    if rec.get("tag"):
        suffix += f"__{rec['tag']}"
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    return name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--strategy", default=None,
                    help="override comm strategy (wfbp|single|mgwfbp|...)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    archs = registry.list_archs() if (args.all or not args.arch) \
        else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = n_skip = 0
    for arch in archs:
        bundle = registry.get_arch(arch)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for shape_name in shapes:
            if shape_name in bundle.skip_shapes:
                print(f"  [SKIP] {arch} × {shape_name}: "
                      f"{bundle.skip_shapes[shape_name]}", flush=True)
                n_skip += 1
                continue
            for mesh_name in meshes:
                suffix = f"__{args.strategy}" if args.strategy not in (
                    None, "mgwfbp") else ""
                fname = os.path.join(
                    args.out,
                    f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
                if args.skip_existing and os.path.exists(fname):
                    try:
                        if json.load(open(fname)).get("ok"):
                            n_skip += 1
                            continue
                    except Exception:
                        pass
                rec = run_cell(arch, shape_name, mesh_name, args.strategy)
                save_artifact(rec, args.out)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"dry-run done: {n_ok} ok, {n_fail} failed, {n_skip} skipped",
          flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
