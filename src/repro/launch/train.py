"""Training launcher: end-to-end driver over the real substrate.

CPU-runnable example (reduced config)::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 50 --devices 8

Production launch is the same entry point with ``--shape train_4k`` and no
``--reduced`` on a real 256/512-chip slice (the dry-run proves those
configs compile; see launch/dryrun.py).
"""

import os
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N virtual host devices (CPU)")
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")

    import dataclasses
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import SHAPES, ShapeConfig
    from repro.data.pipeline import DataPipeline
    from repro.launch.mesh import make_mesh_for, use_mesh
    from repro.models import registry
    from repro.train import checkpoint, fault
    from repro.train.step import build_train_step

    bundle = registry.reduced_arch(args.arch) if args.reduced \
        else registry.get_arch(args.arch)
    shape = SHAPES[args.shape]
    if args.global_batch or args.seq_len:
        shape = ShapeConfig(shape.name, shape.kind,
                            args.seq_len or shape.seq_len,
                            args.global_batch or shape.global_batch)
    n_dev = len(jax.devices())
    mesh = make_mesh_for(n_dev, model_parallel=min(2, n_dev))
    dims = dict(zip(mesh.axis_names, mesh.devices.shape))
    par = dataclasses.replace(bundle.parallel, dp_axes=("data",),
                              attn_chunk=min(bundle.parallel.attn_chunk,
                                             shape.seq_len))
    run = dataclasses.replace(bundle.run_config(args.shape, par),
                              shape=shape)
    model = bundle.model(par)

    with use_mesh(mesh):
        step_fn, init_fn, art = build_train_step(model, run, mesh,
                                                 strategy=args.strategy)
        print(f"arch={bundle.cfg.name} devices={n_dev} mesh={dims} "
              f"plan={art.plan.strategy}:{art.plan.num_buckets} buckets "
              f"over {art.plan.num_tensors} tensors")
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 art.state_pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(init_fn(jax.random.PRNGKey(run.seed)),
                               shardings)
        bsh = NamedSharding(mesh, art.batch_pspec)
        jstep = jax.jit(step_fn, donate_argnums=0)

        ckpt = checkpoint.AsyncCheckpointer(args.ckpt_dir)
        start = 0
        if args.resume:
            latest = checkpoint.latest_step(args.ckpt_dir)
            if latest is not None:
                state, start, _ = checkpoint.restore(args.ckpt_dir, state)
                print(f"resumed from step {start}")

        pipe = DataPipeline(bundle.cfg, shape, seed=run.seed)

        def wrapped_step(state, batch):
            batch = jax.tree.map(lambda x: jax.device_put(x, bsh), batch)
            return jstep(state, batch)

        def on_metrics(step, metrics, dt):
            if step % args.log_every == 0:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms", flush=True)

        state, final = fault.run_with_recovery(
            wrapped_step, state, pipe, ckpt, start, args.steps,
            ckpt_every=args.ckpt_every, on_metrics=on_metrics)
        print(f"done at step {final}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
