"""jit'd wrappers for merged-gradient pack/unpack.

Layout contract: both :func:`pack` and :func:`unpack` speak the TILE-aligned
slot layout of ``kernel.py`` (each leaf zero-padded to a TILE multiple), and
so does the pure-jnp fallback — the layouts are bit-identical, so callers
(``core.bucketer``) never see which path executed.

``interpret=None`` (default) auto-selects Pallas interpret mode on the CPU
backend; where the kernel cannot lower at all (probed once per mode) the
fallback builds the same buffer with pad+concatenate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bucket_pack import kernel as K
from repro.kernels.bucket_pack.ref import pad_flat

MAX_SRCS_PER_CALL = 32   # chunk very large buckets to bound kernel fan-in


def _auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


_KERNEL_OK: dict[bool, bool] = {}


def kernel_usable(interpret: bool) -> bool:
    """Can the Pallas kernel lower in this mode on this backend?  Probed
    once with a tiny compile; a failure selects the jnp fallback (same
    slot-aligned layout) for the life of the process."""
    ok = _KERNEL_OK.get(interpret)
    if ok is None:
        try:
            x = jnp.zeros((K.TILE,), jnp.float32)
            jax.block_until_ready(jax.jit(
                lambda v: K.pack_kernel([v], jnp.float32,
                                        interpret=interpret))(x))
            ok = True
        except Exception:  # noqa: BLE001 — any lowering failure means "no"
            ok = False
        _KERNEL_OK[interpret] = ok
    return ok


def _result_dtype(leaves, dtype):
    if dtype is not None:
        return jnp.dtype(dtype)
    # same default as core.bucketer.pack: mixed-dtype buckets promote
    return jnp.dtype(jnp.result_type(*[l.dtype for l in leaves]))


def pack(leaves, dtype=None, interpret: bool | None = None) -> jax.Array:
    """Pack arbitrary-shaped leaves into one TILE-aligned flat buffer."""
    dtype = _result_dtype(leaves, dtype)
    if interpret is None:
        interpret = _auto_interpret()
    flats = [pad_flat(l) for l in leaves]
    if not kernel_usable(interpret):
        casted = [f.astype(dtype) for f in flats]
        return jnp.concatenate(casted) if len(casted) > 1 else casted[0]
    pieces = []
    for i in range(0, len(flats), MAX_SRCS_PER_CALL):
        group = flats[i:i + MAX_SRCS_PER_CALL]
        pieces.append(K.pack_kernel(group, dtype, interpret=interpret))
    return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)


def unpack(buf: jax.Array, shapes, dtypes, interpret: bool | None = None):
    """Inverse of :func:`pack` (slot offsets recomputed from shapes)."""
    if interpret is None:
        interpret = _auto_interpret()
    if not kernel_usable(interpret):
        from repro.kernels.bucket_pack.ref import unpack_ref
        return unpack_ref(buf, shapes, dtypes)
    out, off = [], 0
    for shape, dt in zip(shapes, dtypes):
        size = 1
        for d in shape:
            size *= d
        padded = size + ((-size) % K.TILE)
        piece = K.unpack_one_kernel(buf, off, padded, buf.dtype,
                                    interpret=interpret)
        out.append(piece[:size].reshape(shape).astype(dt))
        off += padded
    return out
