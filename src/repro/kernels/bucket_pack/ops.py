"""jit'd wrappers for merged-gradient pack/unpack."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bucket_pack import kernel as K
from repro.kernels.bucket_pack.ref import pad_flat

MAX_SRCS_PER_CALL = 32   # chunk very large buckets to bound kernel fan-in


def pack(leaves, dtype=None, interpret: bool = False) -> jax.Array:
    """Pack arbitrary-shaped leaves into one TILE-aligned flat buffer."""
    dtype = jnp.dtype(dtype or leaves[0].dtype)
    flats = [pad_flat(l) for l in leaves]
    pieces = []
    for i in range(0, len(flats), MAX_SRCS_PER_CALL):
        group = flats[i:i + MAX_SRCS_PER_CALL]
        pieces.append(K.pack_kernel(group, dtype, interpret=interpret))
    return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)


def unpack(buf: jax.Array, shapes, dtypes, interpret: bool = False):
    """Inverse of :func:`pack` (slot offsets recomputed from shapes)."""
    out, off = [], 0
    for shape, dt in zip(shapes, dtypes):
        size = 1
        for d in shape:
            size *= d
        padded = size + ((-size) % K.TILE)
        piece = K.unpack_one_kernel(buf, off, padded, buf.dtype,
                                    interpret=interpret)
        out.append(piece[:size].reshape(shape).astype(dt))
        off += padded
    return out
