"""Pure-jnp oracle for bucket pack/unpack (TILE-aligned concatenate)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bucket_pack.kernel import TILE


def pad_flat(x: jax.Array) -> jax.Array:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % TILE
    return jnp.pad(flat, (0, pad)) if pad else flat


def pack_ref(leaves, dtype=None) -> jax.Array:
    # same default as ops.pack / core.bucketer.pack: mixed dtypes promote
    dtype = dtype or jnp.result_type(*[l.dtype for l in leaves])
    return jnp.concatenate([pad_flat(l).astype(dtype) for l in leaves])


def unpack_ref(buf: jax.Array, shapes, dtypes):
    out, off = [], 0
    for shape, dt in zip(shapes, dtypes):
        size = 1
        for d in shape:
            size *= d
        padded = size + ((-size) % TILE)
        out.append(buf[off:off + size].reshape(shape).astype(dt))
        off += padded
    return out
