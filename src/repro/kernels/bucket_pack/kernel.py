"""Merged-gradient pack/unpack Pallas kernels (paper §5.3).

The paper pre-allocates one contiguous buffer per merged-gradient group and
copies member tensors in before a single all-reduce.  On TPU this is a
bandwidth-bound tiled HBM→VMEM→HBM copy; the MXU plays no role — exactly
the kind of op where BlockSpec tiling *is* the whole kernel.

Layout: each member tensor occupies a TILE-aligned slot in the packed
buffer (slot offsets are compile-time constants from the merge plan), so
every grid step copies one aligned [TILE] block.  ``pack`` is a single
pallas_call over all destination tiles; the source for tile *i* is chosen
with static offset comparisons against ``program_id`` (the member count per
bucket is bounded; larger buckets are chunked by ops.py).  ``unpack`` is
one tiled-copy call per member (reads are independent).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 512


def _make_pack_kernel(ranges):
    """Kernel factory: each grid step writes one destination tile; every
    source's index_map pre-loads its (clamped) candidate block and the
    owner is selected by comparing ``program_id`` against the static slot
    ranges — fully resolved to vector selects, no gather."""
    def kern(*refs):
        o_ref = refs[-1]
        srcs = refs[:-1]
        i = pl.program_id(0)
        acc = jnp.zeros((TILE,), o_ref.dtype)
        for s_idx, s_ref in enumerate(srcs):
            lo, hi = ranges[s_idx]
            inside = (i >= lo) & (i < hi)
            acc = jnp.where(inside, s_ref[...].astype(o_ref.dtype), acc)
        o_ref[...] = acc
    return kern


def pack_kernel(srcs: list[jax.Array], dtype, interpret: bool = False
                ) -> jax.Array:
    """srcs: flat arrays, each padded to TILE multiple.  Returns the packed
    [sum(sizes)] buffer with TILE-aligned slots."""
    sizes = [s.shape[0] for s in srcs]
    assert all(sz % TILE == 0 for sz in sizes)
    offs, acc = [], 0
    for sz in sizes:
        offs.append(acc)
        acc += sz
    total = acc
    ranges = [(o // TILE, (o + sz) // TILE) for o, sz in zip(offs, sizes)]

    in_specs = []
    for (lo, hi), sz in zip(ranges, sizes):
        n_tiles = sz // TILE
        in_specs.append(pl.BlockSpec(
            (TILE,),
            functools.partial(
                lambda i, lo=lo, n=n_tiles: (jnp.clip(i - lo, 0, n - 1),))))
    return pl.pallas_call(
        _make_pack_kernel(ranges),
        grid=(total // TILE,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), dtype),
        interpret=interpret,
    )(*srcs)


def _copy_kernel(s_ref, o_ref):
    o_ref[...] = s_ref[...].astype(o_ref.dtype)


def unpack_one_kernel(buf: jax.Array, offset: int, size: int, dtype,
                      interpret: bool = False) -> jax.Array:
    """Copy buf[offset : offset+size] out as its own array (TILE-aligned)."""
    assert offset % TILE == 0 and size % TILE == 0
    lo = offset // TILE
    return pl.pallas_call(
        _copy_kernel,
        grid=(size // TILE,),
        in_specs=[pl.BlockSpec((TILE,), lambda i, lo=lo: (i + lo,))],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((size,), dtype),
        interpret=interpret,
    )(buf)
