"""Flash attention Pallas TPU kernel: blockwise online softmax.

TPU adaptation of the (GPU-origin) flash-attention algorithm: the MXU wants
128-aligned [block_q, head_dim] × [head_dim, block_k] tiles resident in
VMEM; the online-softmax running statistics (m, l) and the output
accumulator live in fp32 VMEM scratch that persists across the innermost
(KV) grid dimension.  Supports GQA (G query heads share one KV head via the
index map), causal masking, and sliding windows (gemma3's local layers).

Layouts:  q [BHq, Sq, D], k/v [BHkv, Skv, D] with BHq = BHkv * G and the
query-head-major flattening (b, kvh, g).  Grid: (BHq, Sq/bq, Skv/bk), KV
innermost with "arbitrary" semantics (sequential accumulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params(**kwargs):
    """TPU compiler params across Pallas versions (CompilerParams on new
    JAX, TPUCompilerParams on 0.4.x)."""
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise NotImplementedError(
            "this Pallas version exposes neither pltpu.CompilerParams nor "
            "pltpu.TPUCompilerParams")
    return cls(**kwargs)

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, seq_q: int, seq_kv: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # [bq, D]
    k = k_ref[0].astype(jnp.float32)              # [bk, D]
    v = v_ref[0].astype(jnp.float32)              # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    qi = pl.program_id(1)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = (k_pos < seq_kv) & (q_pos < seq_q)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None]) * mask
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, scale: float, causal: bool,
                           window: int, block_q: int = 128,
                           block_k: int = 128, seq_q: int, seq_kv: int,
                           interpret: bool = False) -> jax.Array:
    """q: [BHq, Sq_pad, D]; k/v: [BHkv, Skv_pad, D]; Sq_pad % block_q == 0,
    Skv_pad % block_k == 0.  ``seq_q``/``seq_kv`` are the unpadded lengths
    (padding is masked out)."""
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    g = bhq // bhkv
    grid = (bhq, sq // block_q, skv // block_k)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=seq_q, seq_kv=seq_kv)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
