"""jit'd wrapper for the flash-attention kernel: layout, padding, GQA."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Sq, Hq, D]; k/v: [B, Skv, Hkv, D] -> [B, Sq, Hq, D].

    GQA via head-major flattening; sequences padded to block multiples and
    masked inside the kernel.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    scale = 1.0 / math.sqrt(d)

    # [B,S,H,D] -> [B*H, S, D]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    qf = _pad_to(qf, 1, block_q)
    kf = _pad_to(kf, 1, block_k)
    vf = _pad_to(vf, 1, block_k)

    o = flash_attention_kernel(qf, kf, vf, scale=scale, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, seq_q=sq, seq_kv=skv,
                               interpret=interpret)
    o = o[:, :sq].reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
    return o
