"""Pure-jnp oracle for the flash-attention kernel (full materialization)."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jnp.ndarray:
    """q: [B, Sq, Hq, D]; k/v: [B, Skv, Hkv, D] (GQA by head grouping)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m) * mask[None, None, None]
    w = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)
