"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships as <name>/{kernel,ops,ref}.py: pallas_call with explicit
BlockSpec VMEM tiling, a jit'd public wrapper, and a pure-jnp oracle the
tests sweep shapes/dtypes against (interpret=True on CPU)."""
