"""jit'd wrapper for fused RMSNorm: arbitrary leading dims + row padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    o = rmsnorm_kernel(xf, scale, eps=eps, block_rows=block_rows,
                       interpret=interpret)
    return o[:rows].reshape(shape)
