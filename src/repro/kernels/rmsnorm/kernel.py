"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block.

Unfused, XLA materializes the normalized intermediate before the scale
multiply; the fused kernel streams a [block_rows, d] tile through VMEM,
computes the fp32 row mean-square on the VPU and writes the scaled output
in place — pure bandwidth-bound, so the win is one avoided HBM round trip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_kernel(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = False
                   ) -> jax.Array:
    """x: [R, D] (rows padded to block multiple by ops.py); scale: [D]."""
    r, d = x.shape
    assert r % block_rows == 0
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale)
